//! **The solver interface** — one trait, one report type, one dispatch
//! point for every GW engine in the crate.
//!
//! The paper evaluates Spar-GW against a whole family of estimators
//! (entropic/proximal Algorithm 1, SaGroW, low-rank GW, S-GWL, anchor
//! energies, …). Each family member keeps its bespoke free function and
//! typed config — those stay bit-identical and golden-locked — but every
//! one of them also implements [`GwSolver`], so the coordinator, the bench
//! suite and the CLI can select any engine per request by name:
//!
//! * [`GwSolver`] — `solve(&GwProblem, &mut Rng, &mut Workspace)` (plus
//!   `solve_fused` for methods that extend to the fused objective),
//!   returning a uniform [`SolveReport`].
//! * [`SolveReport`] — estimated value, the coupling as a dense-or-sparse
//!   [`Plan`], outer iterations, convergence flag and per-phase
//!   [`PhaseTimings`].
//! * [`SolverRegistry`] — string-keyed construction
//!   (`"spar_gw"`, `"sagrow"`, `"lr_gw"`, …) with solver-specific options
//!   parsed from a `BTreeMap<String, String>` (the CLI's `--solver-opt
//!   k=v`). Unknown names and unknown option keys produce descriptive
//!   errors listing the valid choices.
//! * [`SolverBase`] — typed defaults the string options override, so the
//!   coordinator's `PairwiseConfig` and the bench suite's `RunSettings`
//!   seed per-solver configs without every caller re-spelling them.
//!
//! The solver *implementations* live next to the algorithms they wrap
//! (`spar_gw::SparGwSolver`, `alg1::Alg1Solver`, `sagrow::SagrowSolver`,
//! …); this module owns only the interface and the registry.

use std::collections::BTreeMap;
use std::sync::OnceLock;

use super::alg1::{Alg1Kind, Alg1Solver};
use super::anchor::AnchorSolver;
use super::core::Workspace;
use super::cost::GroundCost;
use super::fgw::FgwProblem;
use super::lr_gw::LrGwSolver;
use super::qgw::QgwSolver;
use super::sagrow::SagrowSolver;
use super::sampling::SideFactors;
use super::sgwl::SgwlSolver;
use super::spar_fgw::SparFgwSolver;
use super::spar_gw::SparGwSolver;
use super::spar_ugw::SparUgwSolver;
use super::{GwProblem, Regularizer};
use crate::kernel::Precision;
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::sparse::Coo;
use crate::util::error::Result;
use crate::{bail, format_err};

/// A factored low-rank coupling `T = Q diag(1/g) Rᵀ` with `Q` m×r, `R`
/// n×r, `g ∈ Δ^{r−1}` — O((m+n)·r) storage. Mass, marginals and
/// finiteness are all evaluated from the factors; the dense m×n matrix is
/// only built by the explicit [`LowRankPlan::reconstruct`] (small-n
/// evaluation paths and the opt-in `dense=1` solver option).
pub struct LowRankPlan {
    /// Left factor, `Q ∈ Π(a, g)` (m×r).
    pub q: Mat,
    /// Right factor, `R ∈ Π(b, g)` (n×r).
    pub r: Mat,
    /// Inner weights (length r, on the simplex).
    pub g: Vec<f64>,
}

impl LowRankPlan {
    /// Coupling rank r.
    pub fn rank(&self) -> usize {
        self.g.len()
    }

    /// `Σ_ij T_ij = Σ_k (Qᵀ1)_k (Rᵀ1)_k / g_k` — O((m+n)r).
    pub fn sum(&self) -> f64 {
        let cq = self.q.col_sums();
        let cr = self.r.col_sums();
        let mut s = 0.0;
        for k in 0..self.g.len() {
            s += cq[k] * cr[k] / self.g[k].max(1e-300);
        }
        s
    }

    /// `T·1 = Q·((Rᵀ1) ∘ g⁻¹)` — O((m+n)r), no densification.
    pub fn row_sums(&self) -> Vec<f64> {
        let mut w = self.r.col_sums();
        for (wk, gk) in w.iter_mut().zip(&self.g) {
            *wk /= gk.max(1e-300);
        }
        self.q.matvec(&w)
    }

    /// `Tᵀ·1 = R·((Qᵀ1) ∘ g⁻¹)`.
    pub fn col_sums(&self) -> Vec<f64> {
        let mut w = self.q.col_sums();
        for (wk, gk) in w.iter_mut().zip(&self.g) {
            *wk /= gk.max(1e-300);
        }
        self.r.matvec(&w)
    }

    /// Stored entries: the factor storage (m+n)·r + r, **not** m·n.
    pub fn nnz(&self) -> usize {
        self.q.rows() * self.q.cols() + self.r.rows() * self.r.cols() + self.g.len()
    }

    /// True if every stored factor entry is finite.
    pub fn is_finite(&self) -> bool {
        self.q.data().iter().all(|v| v.is_finite())
            && self.r.data().iter().all(|v| v.is_finite())
            && self.g.iter().all(|v| v.is_finite())
    }

    /// Materialize the dense m×n coupling. O(m·n·r) time and O(m·n)
    /// memory — small-n evaluation only; the solve path never calls this.
    pub fn reconstruct(&self) -> Mat {
        let (m, n, rank) = (self.q.rows(), self.r.rows(), self.g.len());
        let mut t = Mat::zeros(m, n);
        for i in 0..m {
            let qrow = self.q.row(i);
            let trow = t.row_mut(i);
            for (j, slot) in trow.iter_mut().enumerate() {
                let rrow = self.r.row(j);
                let mut s = 0.0;
                for k in 0..rank {
                    s += qrow[k] * rrow[k] / self.g[k].max(1e-300);
                }
                *slot = s;
            }
        }
        t
    }
}

/// A coupling in whichever representation the solver natively produces:
/// dense (Algorithm-1 family, SaGroW, S-GWL, AE), sparse on the sampled
/// support (the Spar-* family, qgw's extended block plan), or factored
/// low-rank (LR-GW's O((m+n)r) representation).
pub enum Plan {
    /// Full m×n coupling.
    Dense(Mat),
    /// Coupling restricted to a sampled sparsity pattern.
    Sparse(Coo),
    /// Factored low-rank coupling `Q diag(1/g) Rᵀ`.
    Factored(LowRankPlan),
}

impl Plan {
    /// Total transported mass.
    pub fn sum(&self) -> f64 {
        match self {
            Plan::Dense(t) => t.sum(),
            Plan::Sparse(t) => t.sum(),
            Plan::Factored(t) => t.sum(),
        }
    }

    /// Row marginals `T·1`.
    pub fn row_sums(&self) -> Vec<f64> {
        match self {
            Plan::Dense(t) => t.row_sums(),
            Plan::Sparse(t) => t.row_sums(),
            Plan::Factored(t) => t.row_sums(),
        }
    }

    /// Column marginals `Tᵀ·1`.
    pub fn col_sums(&self) -> Vec<f64> {
        match self {
            Plan::Dense(t) => t.col_sums(),
            Plan::Sparse(t) => t.col_sums(),
            Plan::Factored(t) => t.col_sums(),
        }
    }

    /// Stored entries (m·n for dense plans, |S| for sparse ones, the
    /// factor storage for factored ones).
    pub fn nnz(&self) -> usize {
        match self {
            Plan::Dense(t) => t.rows() * t.cols(),
            Plan::Sparse(t) => t.nnz(),
            Plan::Factored(t) => t.nnz(),
        }
    }

    /// True if every stored entry is finite.
    pub fn is_finite(&self) -> bool {
        match self {
            Plan::Dense(t) => t.data().iter().all(|v| v.is_finite()),
            Plan::Sparse(t) => t.vals().iter().all(|v| v.is_finite()),
            Plan::Factored(t) => t.is_finite(),
        }
    }
}

/// Fine-grained per-phase wall-clock breakdown for the hierarchical tier
/// (solvers with more structure than sample + iterate). `Copy` so
/// [`PhaseTimings`] stays a plain value type.
#[derive(Clone, Copy, Debug, Default)]
pub enum PhaseDetail {
    /// No finer breakdown (the historical solvers).
    #[default]
    None,
    /// Quantized GW: partition → coarse solve → local extension.
    Quantized {
        /// Anchor selection + nearest-anchor assignment.
        partition_seconds: f64,
        /// The registry-dispatched inner solve on the anchor problem.
        coarse_seconds: f64,
        /// Local coupling extension within matched partitions.
        extension_seconds: f64,
    },
    /// Low-rank GW: factorization → mirror descent.
    LowRank {
        /// Building the (optional) Nyström factors of the mapped costs.
        factor_seconds: f64,
        /// The factored mirror-descent loop.
        descent_seconds: f64,
    },
}

impl PhaseDetail {
    /// Named (phase, seconds) pairs for metrics/summary display; empty
    /// for `None`.
    pub fn named(&self) -> Vec<(&'static str, f64)> {
        match *self {
            PhaseDetail::None => Vec::new(),
            PhaseDetail::Quantized {
                partition_seconds,
                coarse_seconds,
                extension_seconds,
            } => vec![
                ("partition", partition_seconds),
                ("coarse", coarse_seconds),
                ("extension", extension_seconds),
            ],
            PhaseDetail::LowRank { factor_seconds, descent_seconds } => {
                vec![("factor", factor_seconds), ("descent", descent_seconds)]
            }
        }
    }
}

/// Wall-clock seconds per solve phase.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimings {
    /// Building the sampled index set (0 for dense solvers).
    pub sample_seconds: f64,
    /// The iteration loop (everything after sampling).
    pub solve_seconds: f64,
    /// Finer breakdown of `solve_seconds` where the solver has one.
    pub detail: PhaseDetail,
}

impl PhaseTimings {
    /// The historical two-phase timing (no finer breakdown).
    pub fn basic(sample_seconds: f64, solve_seconds: f64) -> Self {
        PhaseTimings { sample_seconds, solve_seconds, detail: PhaseDetail::None }
    }

    pub fn total(&self) -> f64 {
        self.sample_seconds + self.solve_seconds
    }
}

/// Uniform result of any registered solver.
pub struct SolveReport {
    /// Registry name of the engine that produced this report.
    pub solver: &'static str,
    /// Estimated (F/U)GW value.
    pub value: f64,
    /// Final coupling, dense or sparse.
    pub plan: Plan,
    /// Outer iterations performed (1 for one-shot methods like AE).
    pub outer_iters: usize,
    /// True if the solver's stopping rule fired before its iteration cap
    /// (one-shot exact methods report `true`).
    pub converged: bool,
    /// Per-phase wall-clock timings.
    pub timings: PhaseTimings,
}

/// Immutable per-structure (per metric-measure space) precomputation: the
/// structure's marginal and the Eq. (5) sampling factors over it. In a
/// K×K pairwise Gram computation this is the work that is identical for
/// every pair a structure participates in; the coordinator's
/// `StructureCache` builds one `PreparedStructure` per input exactly once
/// and shares it (immutably) across all pairs, shards and worker threads.
/// The intra-space relation matrix itself is NOT copied here — it stays
/// in the caller's dataset and travels by reference through `GwProblem`,
/// so caching adds no O(n²) memory.
pub struct PreparedStructure {
    /// Marginal distribution over the structure's atoms (length n).
    pub marginal: Vec<f64>,
    /// Eq. (5) importance-sampling factors `√marginal` as an alias table
    /// (f64 precision — the default path).
    pub factors: SideFactors,
    /// Lazily built f32-precision factors, cached per structure so a
    /// mixed-precision Gram run builds them exactly once per input (the
    /// relation matrix itself is never duplicated — only the O(n) factor
    /// table exists per precision).
    factors_f32: OnceLock<SideFactors>,
}

impl PreparedStructure {
    /// Run the per-structure preprocessing once: keeps `marginal` and
    /// derives the sampling factors from it.
    pub fn new(marginal: Vec<f64>) -> Self {
        let factors = SideFactors::new(&marginal);
        PreparedStructure { marginal, factors, factors_f32: OnceLock::new() }
    }

    /// The sampling factors at the requested kernel precision. `F64`
    /// returns the eagerly built table (the historical path, bit-for-bit);
    /// `F32` builds the quantized table on first use and caches it for
    /// every later pair/shard/thread that asks (thread-safe via
    /// `OnceLock`).
    pub fn factors_for(&self, precision: Precision) -> &SideFactors {
        match precision {
            Precision::F64 => &self.factors,
            Precision::F32 => self
                .factors_f32
                .get_or_init(|| SideFactors::with_precision(&self.marginal, Precision::F32)),
        }
    }

    /// Number of atoms.
    pub fn len(&self) -> usize {
        self.marginal.len()
    }

    /// True for a structure with no atoms (never: construction asserts).
    pub fn is_empty(&self) -> bool {
        self.marginal.is_empty()
    }
}

/// The one interface every GW engine implements. Implementations are
/// plain data (`Send + Sync`), so one boxed solver can serve a whole
/// worker pool; per-solve mutable state lives in the caller's `rng` and
/// `ws` (dense solvers ignore the workspace).
pub trait GwSolver: Send + Sync {
    /// Registry name (`"spar_gw"`, `"egw"`, …).
    fn name(&self) -> &'static str;

    /// Solve a balanced (or, for `spar_ugw`, unbalanced) GW problem.
    fn solve(&self, p: &GwProblem, rng: &mut Rng, ws: &mut Workspace) -> Result<SolveReport>;

    /// Whether [`GwSolver::solve_fused`] is supported.
    fn supports_fused(&self) -> bool {
        false
    }

    /// Solve the fused objective `α·GW + (1−α)·⟨M, T⟩` (α and `M` come
    /// with the problem). Structure-only solvers return a descriptive
    /// error.
    fn solve_fused(
        &self,
        p: &FgwProblem,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<SolveReport> {
        let _ = (p, rng, ws);
        bail!(
            "solver {:?} does not support the fused objective (structure-only method)",
            self.name()
        )
    }

    /// [`GwSolver::solve`] with per-side precomputed structures. The
    /// contract is strict: `sx`/`sy` must describe the same spaces as `p`
    /// (`p.a == sx.marginal`, `p.b == sy.marginal`), and the result is
    /// **bit-identical** to `solve` — prepared structures are a pure
    /// amortization, never a semantic switch. The default ignores them
    /// (dense engines have no per-structure reusable state); the Spar-*
    /// samplers override to reuse the cached Eq. (5) factors.
    fn solve_prepared(
        &self,
        p: &GwProblem,
        sx: &PreparedStructure,
        sy: &PreparedStructure,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<SolveReport> {
        let _ = (sx, sy);
        self.solve(p, rng, ws)
    }

    /// [`GwSolver::solve_fused`] with per-side precomputed structures;
    /// same bit-identity contract as [`GwSolver::solve_prepared`].
    /// Structure-only solvers return the same descriptive error as
    /// `solve_fused`.
    fn solve_fused_prepared(
        &self,
        p: &FgwProblem,
        sx: &PreparedStructure,
        sy: &PreparedStructure,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<SolveReport> {
        let _ = (sx, sy);
        self.solve_fused(p, rng, ws)
    }
}

/// Typed defaults that seed every solver's config before string options
/// are applied. The coordinator derives one from `PairwiseConfig`, the
/// bench suite from `RunSettings`; standalone callers use `::default()`.
#[derive(Clone, Copy, Debug)]
pub struct SolverBase {
    /// Ground cost `L`.
    pub cost: GroundCost,
    /// Regularization weight ε.
    pub epsilon: f64,
    /// Sample budget s for the sparsified/sampled methods (0 → 16·max(m,n)).
    pub sample_size: usize,
    /// Outer iteration cap R.
    pub outer_iters: usize,
    /// Inner Sinkhorn iterations H.
    pub inner_iters: usize,
    /// Proximal or entropic regularizer for the Alg. 1/2-style methods.
    pub reg: Regularizer,
    /// Structure/feature trade-off α for fused problems.
    pub alpha: f64,
    /// Shrinkage θ toward uniform sampling (condition H.4).
    pub shrink: f64,
    /// Outer stopping tolerance (0 disables).
    pub tol: f64,
    /// Marginal relaxation weight λ (unbalanced methods).
    pub lambda: f64,
    /// Kernel precision (`f64` default — bit-identical; `f32` = mixed
    /// precision, Spar-* family only).
    pub precision: Precision,
}

impl Default for SolverBase {
    fn default() -> Self {
        SolverBase {
            cost: GroundCost::L2,
            epsilon: 0.01,
            sample_size: 0,
            outer_iters: 20,
            inner_iters: 50,
            reg: Regularizer::Proximal,
            alpha: 0.6,
            shrink: 0.0,
            tol: 1e-9,
            lambda: 1.0,
            precision: Precision::F64,
        }
    }
}

/// Typed view over a solver's string options. Getters record which keys
/// the builder understands; [`Opts::finish`] then rejects any key the
/// builder never asked about, listing the valid ones — so `--solver-opt
/// typo=1` fails loudly instead of being silently ignored.
pub(crate) struct Opts<'a> {
    map: &'a BTreeMap<String, String>,
    known: Vec<&'static str>,
}

impl<'a> Opts<'a> {
    pub(crate) fn new(map: &'a BTreeMap<String, String>) -> Self {
        Opts { map, known: Vec::new() }
    }

    fn raw(&mut self, key: &'static str) -> Option<&'a str> {
        if !self.known.contains(&key) {
            self.known.push(key);
        }
        self.map.get(key).map(|s| s.as_str())
    }

    pub(crate) fn f64(&mut self, key: &'static str, default: f64) -> Result<f64> {
        match self.raw(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format_err!("solver option {key}={v:?}: expected a number")),
        }
    }

    /// Free-form string option (e.g. the name of qgw's inner solver).
    pub(crate) fn string(&mut self, key: &'static str, default: &str) -> Result<String> {
        Ok(self.raw(key).unwrap_or(default).to_string())
    }

    pub(crate) fn usize(&mut self, key: &'static str, default: usize) -> Result<usize> {
        match self.raw(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format_err!("solver option {key}={v:?}: expected an integer")),
        }
    }

    pub(crate) fn cost(&mut self, default: GroundCost) -> Result<GroundCost> {
        match self.raw("cost") {
            None => Ok(default),
            Some("l1") => Ok(GroundCost::L1),
            Some("l2") => Ok(GroundCost::L2),
            Some("kl") => Ok(GroundCost::Kl),
            Some(v) => bail!("solver option cost={v:?}: expected l1|l2|kl"),
        }
    }

    pub(crate) fn reg(&mut self, default: Regularizer) -> Result<Regularizer> {
        match self.raw("reg") {
            None => Ok(default),
            Some("proximal") => Ok(Regularizer::Proximal),
            Some("entropy") => Ok(Regularizer::Entropy),
            Some(v) => bail!("solver option reg={v:?}: expected proximal|entropy"),
        }
    }

    pub(crate) fn precision(&mut self, default: Precision) -> Result<Precision> {
        match self.raw("precision") {
            None => Ok(default),
            // One parser for the whole crate (case-insensitive, like
            // solver names); only the error prefix is option-specific.
            Some(v) => Precision::parse(v)
                .map_err(|_| format_err!("solver option precision={v:?}: expected f32|f64")),
        }
    }

    /// For engines whose kernels are f64-only: accept `precision=f64`
    /// (and the default), reject `precision=f32` with a one-line error
    /// naming the solver and the values it supports.
    pub(crate) fn precision_f64_only(
        &mut self,
        solver: &'static str,
        default: Precision,
    ) -> Result<()> {
        match self.precision(default)? {
            Precision::F64 => Ok(()),
            Precision::F32 => bail!(
                "solver {solver:?} does not support precision=f32 \
                 (supported: f64; f32 is available for: {})",
                F32_SOLVERS.join(", ")
            ),
        }
    }

    pub(crate) fn finish(mut self, solver: &str) -> Result<()> {
        self.known.sort_unstable();
        for key in self.map.keys() {
            if !self.known.contains(&key.as_str()) {
                bail!(
                    "unknown option {key:?} for solver {solver:?} (valid keys: {})",
                    self.known.join(", ")
                );
            }
        }
        Ok(())
    }
}

/// String-keyed construction of every GW engine in the crate.
pub struct SolverRegistry;

/// Registry names in the paper's presentation order, plus the
/// hierarchical tier (`qgw`).
const SOLVER_NAMES: &[&str] = &[
    "spar_gw", "spar_fgw", "spar_ugw", "egw", "pga_gw", "emd_gw", "sagrow", "lr_gw", "sgwl",
    "anchor", "qgw",
];

/// The solvers whose engine loop supports `precision=f32` (the SparCore
/// family, plus `qgw` whose default inner solve runs on that family);
/// everyone else is f64-only and rejects the option descriptively.
const F32_SOLVERS: &[&str] = &["spar_gw", "spar_fgw", "spar_ugw", "qgw"];

/// Case/punctuation-insensitive key: `"Spar-GW"` ≡ `"spar_gw"`.
pub(crate) fn normalize(name: &str) -> String {
    name.chars()
        .filter(|c| c.is_ascii_alphanumeric())
        .collect::<String>()
        .to_ascii_lowercase()
}

impl SolverRegistry {
    /// All registered solver names.
    pub fn names() -> &'static [&'static str] {
        SOLVER_NAMES
    }

    /// Whether the named solver supports `precision=f32` (the SparCore
    /// family does; the dense comparators are f64-only). Unknown names
    /// return `false`.
    pub fn supports_f32(name: &str) -> bool {
        let key = normalize(name);
        F32_SOLVERS.iter().any(|&s| normalize(s) == key)
    }

    /// The precisions the named solver accepts, for display.
    pub fn precisions(name: &str) -> &'static str {
        if Self::supports_f32(name) {
            "f32, f64"
        } else {
            "f64"
        }
    }

    /// The numerics tiers the named solver runs under, for display.
    /// Every solver supports both policies (the tier lives in the shared
    /// kernel layer, not in any engine); the SparCore family additionally
    /// gets the fused spmv+scaling sweeps under fast, so its tag calls
    /// that out. Unknown names show the shared-kernel default.
    pub fn numerics(name: &str) -> &'static str {
        if Self::supports_f32(name) {
            "strict, fast (fused sweeps)"
        } else {
            "strict, fast"
        }
    }

    /// Build a solver by name with library defaults plus `opts` overrides.
    pub fn build(name: &str, opts: &BTreeMap<String, String>) -> Result<Box<dyn GwSolver>> {
        Self::build_with_base(name, opts, &SolverBase::default())
    }

    /// Build a solver by name: `base` seeds the config, `opts` overrides
    /// individual fields. Unknown names and unknown option keys error
    /// descriptively.
    pub fn build_with_base(
        name: &str,
        opts: &BTreeMap<String, String>,
        base: &SolverBase,
    ) -> Result<Box<dyn GwSolver>> {
        let mut o = Opts::new(opts);
        let solver: Box<dyn GwSolver> = match normalize(name).as_str() {
            "spargw" => Box::new(SparGwSolver::from_opts(base, &mut o)?),
            "sparfgw" => Box::new(SparFgwSolver::from_opts(base, &mut o)?),
            "sparugw" => Box::new(SparUgwSolver::from_opts(base, &mut o)?),
            "egw" => Box::new(Alg1Solver::from_opts(Alg1Kind::Egw, base, &mut o)?),
            "pgagw" => Box::new(Alg1Solver::from_opts(Alg1Kind::PgaGw, base, &mut o)?),
            "emdgw" => Box::new(Alg1Solver::from_opts(Alg1Kind::EmdGw, base, &mut o)?),
            "sagrow" => Box::new(SagrowSolver::from_opts(base, &mut o)?),
            "lrgw" => Box::new(LrGwSolver::from_opts(base, &mut o)?),
            "sgwl" => Box::new(SgwlSolver::from_opts(base, &mut o)?),
            "anchor" | "ae" => Box::new(AnchorSolver::from_opts(base, &mut o)?),
            "qgw" => Box::new(QgwSolver::from_opts(base, &mut o)?),
            _ => bail!(
                "unknown solver {name:?} (valid solvers: {})",
                SOLVER_NAMES.join(", ")
            ),
        };
        o.finish(name)?;
        Ok(solver)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_normalized_keys() {
        for &name in SolverRegistry::names() {
            assert!(
                SolverRegistry::build(name, &BTreeMap::new()).is_ok(),
                "{name} must be constructible"
            );
        }
        // Punctuation/case variants resolve to the same solver.
        assert!(SolverRegistry::build("Spar-GW", &BTreeMap::new()).is_ok());
        assert!(SolverRegistry::build("PGA_GW", &BTreeMap::new()).is_ok());
    }

    #[test]
    fn unknown_name_lists_choices() {
        let err = SolverRegistry::build("warp_drive", &BTreeMap::new()).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("unknown solver"), "{msg}");
        for &name in SolverRegistry::names() {
            assert!(msg.contains(name), "{msg} missing {name}");
        }
    }

    #[test]
    fn unknown_option_key_lists_valid_keys() {
        let mut opts = BTreeMap::new();
        opts.insert("warp".to_string(), "9".to_string());
        let err = SolverRegistry::build("spar_gw", &opts).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("warp"), "{msg}");
        assert!(msg.contains("epsilon"), "{msg} should list valid keys");
    }

    #[test]
    fn malformed_option_value_is_descriptive() {
        let mut opts = BTreeMap::new();
        opts.insert("epsilon".to_string(), "abc".to_string());
        let err = SolverRegistry::build("spar_gw", &opts).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("epsilon"), "{msg}");
        assert!(msg.contains("number"), "{msg}");
    }

    #[test]
    fn precision_support_table() {
        for &name in F32_SOLVERS {
            assert!(SolverRegistry::supports_f32(name), "{name}");
            assert_eq!(SolverRegistry::precisions(name), "f32, f64");
        }
        for &name in &["egw", "pga_gw", "emd_gw", "sagrow", "lr_gw", "sgwl", "anchor"] {
            assert!(!SolverRegistry::supports_f32(name), "{name}");
            assert_eq!(SolverRegistry::precisions(name), "f64");
        }
        // Case/punctuation-insensitive, like the registry itself.
        assert!(SolverRegistry::supports_f32("Spar-GW"));
    }

    #[test]
    fn every_solver_accepts_the_precision_key_at_f64() {
        let mut opts = BTreeMap::new();
        opts.insert("precision".to_string(), "f64".to_string());
        for &name in SolverRegistry::names() {
            assert!(
                SolverRegistry::build(name, &opts).is_ok(),
                "{name} must accept precision=f64"
            );
        }
    }

    #[test]
    fn f64_only_solvers_reject_f32_with_one_line_error() {
        let mut opts = BTreeMap::new();
        opts.insert("precision".to_string(), "f32".to_string());
        for &name in SolverRegistry::names() {
            let r = SolverRegistry::build(name, &opts);
            if SolverRegistry::supports_f32(name) {
                assert!(r.is_ok(), "{name} must accept precision=f32");
            } else {
                let msg = format!("{}", r.unwrap_err());
                assert!(!msg.contains('\n'), "{name}: not one line: {msg}");
                assert!(msg.contains(name), "{name}: {msg}");
                assert!(msg.contains("f64"), "{name}: {msg} should name the valid value");
            }
        }
    }

    #[test]
    fn malformed_precision_lists_valid_values() {
        let mut opts = BTreeMap::new();
        opts.insert("precision".to_string(), "f16".to_string());
        let msg = format!("{}", SolverRegistry::build("spar_gw", &opts).unwrap_err());
        assert!(msg.contains("f32"), "{msg}");
        assert!(msg.contains("f64"), "{msg}");
    }

    #[test]
    fn prepared_structure_caches_per_precision_factors() {
        let ps = PreparedStructure::new(vec![0.25, 0.25, 0.5]);
        let f64a = ps.factors_for(Precision::F64) as *const _;
        let f64b = ps.factors_for(Precision::F64) as *const _;
        assert_eq!(f64a, f64b, "f64 factors must be the eager table");
        let f32a = ps.factors_for(Precision::F32) as *const _;
        let f32b = ps.factors_for(Precision::F32) as *const _;
        assert_eq!(f32a, f32b, "f32 factors must be built once and cached");
    }
}
