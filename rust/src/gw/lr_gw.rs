//! LR-GW — Linear-time Gromov-Wasserstein with low-rank couplings
//! (Scetbon, Peyré & Cuturi 2022), the "quadratic approach" variant used
//! as a comparator in §6.1 — now on a **factored O((m+n)r)-memory path**.
//!
//! The coupling is constrained to `T = Q diag(1/g) Rᵀ` with
//! `Q ∈ Π(a, g)`, `R ∈ Π(b, g)`, `g ∈ Δ^{r−1}` (rank r, paper setting
//! r = ⌈n/20⌉) and is **never materialized**: all mirror-descent
//! quantities are expressed through the factors.
//!
//! With the decomposable ground cost `L(x, y) = f1(x) + f2(y) − h1(x)h2(y)`
//! the GW gradient is `C(T) = term1 ⊕ term2 − HQ diag(1/g) HRᵀ` where
//! `term1 = f1(Cx)·(T1)`, `HQ = h1(Cx)·Q`, `HR = h2(Cy)·R`. The factor
//! gradients contract this against `R diag(1/g)` / `Q diag(1/g)` without
//! ever forming the m×n matrix:
//!
//! * `∇Q = term1 ⊗ u₁ + 1 ⊗ v₁ − HQ·W₁` with r-vectors `u₁, v₁` and the
//!   r×r matrix `W₁ = diag(1/g)(HRᵀR)diag(1/g)` — O(mr²);
//! * `∇R`, `∇g` symmetrically from the same r×r contractions;
//! * the objective `⟨C(T), T⟩` from `term1·(T1) + term2·(Tᵀ1) −
//!   Σ_{k,l}(HQᵀQ)[l,k](HRᵀR)[l,k]/(g_l g_k)`.
//!
//! The mapped matrices `f1(Cx)`, `h1(Cx)`, … are **never allocated**
//! either: they act as operators, either streamed row-blockwise over the
//! input relation (mapping entries on the fly; pool-parallel with
//! row-independent accumulation, hence bit-identical at any width) or —
//! opt-in via `landmarks=c` — through a rank-c Nyström factorization
//! `M ≈ C W⁺ Cᵀ` built from c deterministic landmark columns, which makes
//! the per-iteration cost O(n·c·r) instead of O(n²·r).
//!
//! The solver returns [`Plan::Factored`]; dense reconstruction is opt-in
//! (`dense=1`, small n only) and used by the historical free function.

use std::time::Instant;

use super::core::Workspace;
use super::cost::GroundCost;
use super::solver::{
    GwSolver, LowRankPlan, Opts, PhaseDetail, PhaseTimings, Plan, SolveReport, SolverBase,
};
use super::{DenseGwResult, GwProblem};
use crate::ensure;
use crate::linalg::{symmetric_eigen, Mat};
use crate::ot::sinkhorn;
use crate::rng::Rng;
use crate::runtime::pool::pool;
use crate::util::error::Result;

/// Configuration for LR-GW.
#[derive(Clone, Copy, Debug)]
pub struct LrGwConfig {
    /// Coupling rank r (0 → ⌈n/20⌉, the paper's setting).
    pub rank: usize,
    /// Mirror-descent step size γ.
    pub step: f64,
    /// Outer iterations.
    pub outer_iters: usize,
    /// Sinkhorn iterations per factor projection.
    pub proj_iters: usize,
    /// Nyström landmarks c for the mapped relation operators (0 → exact
    /// streaming; c > 0 → rank-c factorization, O(ncr) per iteration).
    pub landmarks: usize,
    /// Materialize the dense plan in the report (small n only; the
    /// factored representation is the default).
    pub dense_plan: bool,
}

impl Default for LrGwConfig {
    fn default() -> Self {
        LrGwConfig {
            rank: 0,
            step: 1.0,
            outer_iters: 30,
            proj_iters: 50,
            landmarks: 0,
            dense_plan: false,
        }
    }
}

/// A mapped relation matrix `f ∘ C` acting as an operator, without the
/// O(n²) allocation of the mapped copy.
enum MappedOp<'a> {
    /// Stream over the stored relation, applying `f` on the fly.
    Exact { c: &'a Mat, f: fn(f64) -> f64 },
    /// Nyström factorization `f∘C ≈ L W⁺ Lᵀ` (L = n×c landmark columns).
    Nystrom { l: Mat, winv: Mat },
}

impl MappedOp<'_> {
    /// `y = (f∘C)·x`. Exact path streams rows on the worker pool
    /// (row-independent fixed-order accumulation — bit-identical at any
    /// width); Nyström path is three small matvecs.
    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        match self {
            MappedOp::Exact { c, f } => {
                let n = c.rows();
                let mut y = vec![0.0; n];
                pool().for_each_chunk_mut(&mut y, 64, |chunk, range, _| {
                    for (slot, i) in chunk.iter_mut().zip(range) {
                        let row = c.row(i);
                        let mut s = 0.0;
                        for (j, &cij) in row.iter().enumerate() {
                            s += f(cij) * x[j];
                        }
                        *slot = s;
                    }
                });
                y
            }
            MappedOp::Nystrom { l, winv } => l.matvec(&winv.matvec(&l.matvec_t(x))),
        }
    }

    /// `Y = (f∘C)·X` for a thin n×r factor `X`.
    fn matmul(&self, x: &Mat) -> Mat {
        match self {
            MappedOp::Exact { c, f } => {
                let n = c.rows();
                let r = x.cols();
                let mut y = Mat::zeros(n, r);
                pool().for_each_row_chunk_mut(y.data_mut(), r, 16, |chunk, range, _| {
                    for (bi, i) in range.enumerate() {
                        let out = &mut chunk[bi * r..(bi + 1) * r];
                        let row = c.row(i);
                        for (j, &cij) in row.iter().enumerate() {
                            let v = f(cij);
                            let xr = x.row(j);
                            for (o, &xk) in out.iter_mut().zip(xr) {
                                *o += v * xk;
                            }
                        }
                    }
                });
                y
            }
            MappedOp::Nystrom { l, winv } => l.matmul(&winv.matmul(&l.transpose().matmul(x))),
        }
    }
}

/// Build the mapped operator: exact streaming (landmarks = 0) or a rank-c
/// Nyström factorization from c evenly spaced landmark indices
/// (deterministic — index t ↦ ⌊t·n/c⌋, strictly increasing for c ≤ n).
fn mapped_op(c: &Mat, f: fn(f64) -> f64, landmarks: usize) -> MappedOp<'_> {
    if landmarks == 0 {
        return MappedOp::Exact { c, f };
    }
    let n = c.rows();
    let cc = landmarks.clamp(1, n);
    let idx: Vec<usize> = (0..cc).map(|t| t * n / cc).collect();
    let mut l = Mat::zeros(n, cc);
    pool().for_each_row_chunk_mut(l.data_mut(), cc, 64, |chunk, range, _| {
        for (bi, i) in range.enumerate() {
            let out = &mut chunk[bi * cc..(bi + 1) * cc];
            for (t, &jt) in idx.iter().enumerate() {
                out[t] = f(c[(i, jt)]);
            }
        }
    });
    let w = Mat::from_fn(cc, cc, |s, t| f(c[(idx[s], idx[t])]));
    // Pseudo-inverse via the Jacobi eigendecomposition, truncating the
    // near-null spectrum (relative tolerance).
    let eig = symmetric_eigen(&w, 60);
    let lam_max = eig.values.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
    let tol = lam_max * 1e-10;
    let mut winv = Mat::zeros(cc, cc);
    for (k, &lam) in eig.values.iter().enumerate() {
        if lam.abs() <= tol {
            continue;
        }
        let inv = 1.0 / lam;
        for s in 0..cc {
            let vs = eig.vectors[(s, k)];
            for t in 0..cc {
                winv[(s, t)] += inv * vs * eig.vectors[(t, k)];
            }
        }
    }
    MappedOp::Nystrom { l, winv }
}

/// `Aᵀ·B` for two thin n×r factors (r×r Gram contraction).
fn gram_t(a: &Mat, b: &Mat) -> Mat {
    a.transpose().matmul(b)
}

/// Result of a factored LR-GW solve: the O((m+n)r) plan plus phase
/// timings (factorization vs descent).
pub struct LrGwFactoredResult {
    /// The factored coupling.
    pub plan: LowRankPlan,
    /// GW energy `⟨C(T), T⟩` evaluated from the factors.
    pub value: f64,
    /// Outer iterations performed.
    pub outer_iters: usize,
    /// True if the stopping rule fired (the fixed-schedule descent runs
    /// to its cap: always false, matching the historical behavior).
    pub converged: bool,
    /// Seconds building the mapped operators (Nyström factorization).
    pub factor_seconds: f64,
    /// Seconds in the mirror-descent loop.
    pub descent_seconds: f64,
}

/// Run factored LR-GW. Only decomposable costs are supported (the paper
/// runs LR-GW with ℓ2 only); panics on ℓ1.
pub fn lr_gw_factored(p: &GwProblem, cost: GroundCost, cfg: &LrGwConfig) -> LrGwFactoredResult {
    let d = cost
        .decomposition()
        .expect("LR-GW requires a decomposable ground cost (paper: ℓ2 only)");
    let (m, n) = (p.m(), p.n());
    let rank = if cfg.rank == 0 { n.div_ceil(20).max(2) } else { cfg.rank.max(2) };
    let floor = 1e-300f64;

    // Mapped relation operators — never densified.
    let t0 = Instant::now();
    let f1cx = mapped_op(p.cx, d.f1, cfg.landmarks);
    let h1cx = mapped_op(p.cx, d.h1, cfg.landmarks);
    let f2cy = mapped_op(p.cy, d.f2, cfg.landmarks);
    let h2cy = mapped_op(p.cy, d.h2, cfg.landmarks);
    let factor_seconds = t0.elapsed().as_secs_f64();

    // Initialize: g uniform, Q = a gᵀ, R = b gᵀ (independent couplings).
    let t1 = Instant::now();
    let g0: Vec<f64> = vec![1.0 / rank as f64; rank];
    let mut q = Mat::outer(p.a, &g0);
    let mut r = Mat::outer(p.b, &g0);
    let mut g = g0;

    let mut outer = 0;
    for _ in 0..cfg.outer_iters {
        let row_marg = q.row_sums(); // ≈ T1 (R ∈ Π(b,g) post-projection)
        let col_marg = r.row_sums();
        let term1 = f1cx.matvec(&row_marg); // m
        let term2 = f2cy.matvec(&col_marg); // n
        let hq = h1cx.matmul(&q); // m×r
        let hr = h2cy.matmul(&r); // n×r

        let colsum_q = q.col_sums(); // r
        let colsum_r = r.col_sums();
        let qt_term1 = q.matvec_t(&term1); // r
        let rt_term2 = r.matvec_t(&term2);
        let hq_q = gram_t(&hq, &q); // r×r: (HQᵀQ)[l,k]
        let hr_r = gram_t(&hr, &r); // r×r: (HRᵀR)[l,k]

        // ∇Q[i,k] = term1[i]·u1[k] + v1[k] − Σ_l hq[i,l]·W1[l,k]
        // with u1 = (Rᵀ1)∘g⁻¹, v1 = (Rᵀterm2)∘g⁻¹,
        // W1 = diag(1/g)(HRᵀR)diag(1/g).
        let u1: Vec<f64> = (0..rank).map(|k| colsum_r[k] / g[k].max(floor)).collect();
        let v1: Vec<f64> = (0..rank).map(|k| rt_term2[k] / g[k].max(floor)).collect();
        let w1 = Mat::from_fn(rank, rank, |l, k| {
            hr_r[(l, k)] / (g[l].max(floor) * g[k].max(floor))
        });
        let mut grad_q = hq.matmul(&w1); // m×r
        for i in 0..m {
            let t1i = term1[i];
            let row = grad_q.row_mut(i);
            for k in 0..rank {
                row[k] = t1i * u1[k] + v1[k] - row[k];
            }
        }

        // ∇R symmetrically through (HQᵀQ).
        let u2: Vec<f64> = (0..rank).map(|k| colsum_q[k] / g[k].max(floor)).collect();
        let v2: Vec<f64> = (0..rank).map(|k| qt_term1[k] / g[k].max(floor)).collect();
        let w2 = Mat::from_fn(rank, rank, |l, k| {
            hq_q[(l, k)] / (g[l].max(floor) * g[k].max(floor))
        });
        let mut grad_r = hr.matmul(&w2); // n×r
        for j in 0..n {
            let t2j = term2[j];
            let row = grad_r.row_mut(j);
            for k in 0..rank {
                row[k] = t2j * u2[k] + v2[k] - row[k];
            }
        }

        // ∇g_k = −(QᵀC(T)R)_kk / g_k², diagonal from the r×r contractions.
        let grad_g: Vec<f64> = (0..rank)
            .map(|k| {
                let mut cross = 0.0;
                for l in 0..rank {
                    cross += hq_q[(l, k)] * hr_r[(l, k)] / g[l].max(floor);
                }
                let qtgr = qt_term1[k] * colsum_r[k] + colsum_q[k] * rt_term2[k] - cross;
                -qtgr / (g[k] * g[k]).max(floor)
            })
            .collect();

        // Mirror (multiplicative) steps with normalization-stabilized rates.
        let scale_q = cfg.step / (1.0 + grad_q.max_abs());
        let mut q_new = Mat::zeros(m, rank);
        for i in 0..m {
            let (qrow, grow) = (q.row(i), grad_q.row(i));
            let nrow = q_new.row_mut(i);
            for k in 0..rank {
                nrow[k] = (qrow[k].max(floor)) * (-scale_q * grow[k]).exp();
            }
        }
        let scale_r = cfg.step / (1.0 + grad_r.max_abs());
        let mut r_new = Mat::zeros(n, rank);
        for j in 0..n {
            let (rrow, grow) = (r.row(j), grad_r.row(j));
            let nrow = r_new.row_mut(j);
            for k in 0..rank {
                nrow[k] = (rrow[k].max(floor)) * (-scale_r * grow[k]).exp();
            }
        }
        let g_absmax = grad_g.iter().fold(0.0f64, |mx, &x| mx.max(x.abs()));
        let scale_g = cfg.step / (1.0 + g_absmax);
        let mut g_new: Vec<f64> = g
            .iter()
            .zip(&grad_g)
            .map(|(&gk, &dk)| gk.max(floor) * (-scale_g * dk).exp())
            .collect();
        crate::util::normalize(&mut g_new);
        g = g_new;

        // Project factors back onto their polytopes: Q ∈ Π(a, g), R ∈ Π(b, g).
        q = sinkhorn(p.a, &g, &q_new, cfg.proj_iters, 0.0).plan;
        r = sinkhorn(p.b, &g, &r_new, cfg.proj_iters, 0.0).plan;
        outer += 1;
    }

    // Objective from the final factors — O((m+n)r + r² + streaming pass),
    // no m×n reconstruction.
    let plan = LowRankPlan { q, r, g };
    let t_rows = plan.row_sums();
    let t_cols = plan.col_sums();
    let term1 = f1cx.matvec(&t_rows);
    let term2 = f2cy.matvec(&t_cols);
    let hq = h1cx.matmul(&plan.q);
    let hr = h2cy.matmul(&plan.r);
    let hq_q = gram_t(&hq, &plan.q);
    let hr_r = gram_t(&hr, &plan.r);
    let mut value = 0.0;
    for i in 0..m {
        value += term1[i] * t_rows[i];
    }
    for j in 0..n {
        value += term2[j] * t_cols[j];
    }
    let rank = plan.rank();
    for l in 0..rank {
        for k in 0..rank {
            value -=
                hq_q[(l, k)] * hr_r[(l, k)] / (plan.g[l].max(floor) * plan.g[k].max(floor));
        }
    }

    LrGwFactoredResult {
        plan,
        value,
        outer_iters: outer,
        converged: false,
        factor_seconds,
        descent_seconds: t1.elapsed().as_secs_f64(),
    }
}

/// Run LR-GW and materialize the dense coupling (the historical API, for
/// small-n evaluation; the solve itself is the factored path). Panics on
/// non-decomposable costs (ℓ1).
pub fn lr_gw(p: &GwProblem, cost: GroundCost, cfg: &LrGwConfig) -> DenseGwResult {
    let r = lr_gw_factored(p, cost, cfg);
    DenseGwResult {
        value: r.value,
        plan: r.plan.reconstruct(),
        outer_iters: r.outer_iters,
        converged: r.converged,
    }
}

/// Registry solver for LR-GW (`"lr_gw"`). Deterministic mirror descent on
/// the factored coupling; requires a decomposable ground cost (the
/// registry path reports a descriptive error on ℓ1 instead of the free
/// function's panic). The mirror-descent schedule keeps its own defaults
/// (rank ⌈n/20⌉, 30 outer steps) rather than inheriting the
/// Sinkhorn-style base caps; override via `rank=` / `step=` / `outer=` /
/// `proj=` options. `landmarks=c` switches the mapped relation operators
/// to a rank-c Nyström factorization; `dense=1` opts into the dense plan
/// reconstruction (small n only).
pub struct LrGwSolver {
    /// Ground cost `L` (must be decomposable).
    pub cost: GroundCost,
    /// LR-GW parameters.
    pub cfg: LrGwConfig,
}

impl LrGwSolver {
    pub(crate) fn from_opts(base: &SolverBase, o: &mut Opts) -> Result<Self> {
        o.precision_f64_only("lr_gw", base.precision)?;
        let d = LrGwConfig::default();
        Ok(LrGwSolver {
            cost: o.cost(base.cost)?,
            cfg: LrGwConfig {
                rank: o.usize("rank", d.rank)?,
                step: o.f64("step", d.step)?,
                outer_iters: o.usize("outer", d.outer_iters)?,
                proj_iters: o.usize("proj", d.proj_iters)?,
                landmarks: o.usize("landmarks", d.landmarks)?,
                dense_plan: o.usize("dense", 0)? != 0,
            },
        })
    }
}

impl GwSolver for LrGwSolver {
    fn name(&self) -> &'static str {
        "lr_gw"
    }

    fn solve(&self, p: &GwProblem, _rng: &mut Rng, _ws: &mut Workspace) -> Result<SolveReport> {
        ensure!(
            self.cost.is_decomposable(),
            "lr_gw requires a decomposable ground cost (l2 or kl), got {}",
            self.cost.name()
        );
        let r = lr_gw_factored(p, self.cost, &self.cfg);
        let plan = if self.cfg.dense_plan {
            Plan::Dense(r.plan.reconstruct())
        } else {
            Plan::Factored(r.plan)
        };
        Ok(SolveReport {
            solver: self.name(),
            value: r.value,
            plan,
            outer_iters: r.outer_iters,
            converged: r.converged,
            timings: PhaseTimings {
                sample_seconds: 0.0,
                solve_seconds: r.factor_seconds + r.descent_seconds,
                detail: PhaseDetail::LowRank {
                    factor_seconds: r.factor_seconds,
                    descent_seconds: r.descent_seconds,
                },
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::util::uniform;

    fn relation(n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let pts: Vec<[f64; 2]> = (0..n).map(|_| [rng.f64(), rng.f64()]).collect();
        Mat::from_fn(n, n, |i, j| crate::linalg::sqdist(&pts[i], &pts[j]).sqrt())
    }

    #[test]
    fn coupling_is_feasible() {
        let n = 12;
        let c1 = relation(n, 1);
        let c2 = relation(n, 2);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let r = lr_gw(&p, GroundCost::L2, &LrGwConfig::default());
        let rows = r.plan.row_sums();
        let cols = r.plan.col_sums();
        for i in 0..n {
            assert!((rows[i] - a[i]).abs() < 1e-4, "row {i}: {}", rows[i]);
            assert!((cols[i] - a[i]).abs() < 1e-4, "col {i}: {}", cols[i]);
        }
    }

    #[test]
    fn improves_over_naive_plan() {
        let n = 14;
        let c1 = relation(n, 3);
        let mut c2 = relation(n, 3); // same space, perturbed
        for i in 0..n {
            for j in 0..n {
                c2[(i, j)] *= 1.02;
            }
        }
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let naive = super::super::tensor::gw_energy(&c1, &c2, &Mat::outer(&a, &a), GroundCost::L2);
        let r = lr_gw(&p, GroundCost::L2, &LrGwConfig { outer_iters: 40, ..Default::default() });
        assert!(r.value <= naive + 1e-9, "lr {} vs naive {naive}", r.value);
    }

    #[test]
    #[should_panic(expected = "decomposable")]
    fn rejects_l1() {
        let n = 5;
        let c = relation(n, 4);
        let a = uniform(n);
        let p = GwProblem::new(&c, &c, &a, &a);
        lr_gw(&p, GroundCost::L1, &LrGwConfig::default());
    }

    #[test]
    fn plan_has_low_rank_structure() {
        // Rank-r coupling: the reconstruction T = Q diag(1/g) Rᵀ has rank
        // ≤ r. Verify via the Jacobi eigenvalues of TᵀT (≤ r non-zeros).
        let n = 10;
        let c1 = relation(n, 5);
        let c2 = relation(n, 6);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let rank = 3;
        let cfg = LrGwConfig { rank, outer_iters: 10, ..Default::default() };
        let r = lr_gw(&p, GroundCost::L2, &cfg);
        let tt = r.plan.transpose().matmul(&r.plan);
        let eig = crate::linalg::symmetric_eigen(&tt, 60);
        let nonzero = eig.values.iter().filter(|&&l| l > 1e-12).count();
        assert!(nonzero <= rank, "rank {nonzero} > {rank}");
    }

    #[test]
    fn factored_value_matches_dense_reconstruction_energy() {
        // The factor-side objective must equal ⟨C(T), T⟩ evaluated on the
        // reconstructed dense coupling (same math, different contraction
        // order — tolerance, not bit, equality).
        let n = 13;
        let c1 = relation(n, 7);
        let c2 = relation(n, 8);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let r = lr_gw_factored(&p, GroundCost::L2, &LrGwConfig::default());
        let t = r.plan.reconstruct();
        let dense_e = super::super::tensor::gw_energy(&c1, &c2, &t, GroundCost::L2);
        assert!(
            (r.value - dense_e).abs() <= 1e-8 * dense_e.abs().max(1.0),
            "factored {} vs dense {dense_e}",
            r.value
        );
        // Factor-side marginals match the reconstruction's too.
        let (fr, dr) = (r.plan.row_sums(), t.row_sums());
        for i in 0..n {
            assert!((fr[i] - dr[i]).abs() < 1e-10, "row {i}: {} vs {}", fr[i], dr[i]);
        }
    }

    #[test]
    fn solver_returns_factored_plan_by_default_and_dense_on_request() {
        use std::collections::BTreeMap;
        let n = 12;
        let c1 = relation(n, 9);
        let c2 = relation(n, 10);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let base = SolverBase::default();
        let build = |opts: &[(&str, &str)]| {
            let map: BTreeMap<String, String> =
                opts.iter().map(|&(k, v)| (k.to_string(), v.to_string())).collect();
            crate::gw::SolverRegistry::build_with_base("lr_gw", &map, &base).unwrap()
        };
        let mut rng = Xoshiro256::new(1);
        let mut ws = Workspace::new();
        let rf = build(&[("outer", "5")]).solve(&p, &mut rng, &mut ws).unwrap();
        match &rf.plan {
            Plan::Factored(lr) => {
                // O((m+n)r) storage, not m·n.
                assert!(rf.plan.nnz() < n * n, "factored nnz {}", rf.plan.nnz());
                assert!(lr.rank() >= 2);
            }
            _ => panic!("default lr_gw plan must be factored"),
        }
        let rd = build(&[("outer", "5"), ("dense", "1")]).solve(&p, &mut rng, &mut ws).unwrap();
        match &rd.plan {
            Plan::Dense(t) => {
                assert_eq!(t.shape(), (n, n));
                // Same trajectory: dense is the reconstruction of the factors.
                assert!((rd.value - rf.value).abs() < 1e-12);
            }
            _ => panic!("dense=1 must materialize the plan"),
        }
        match rf.timings.detail {
            PhaseDetail::LowRank { .. } => {}
            _ => panic!("lr_gw must report low-rank phase detail"),
        }
    }

    #[test]
    fn nystrom_landmarks_path_runs_and_stays_feasible() {
        let n = 16;
        let c1 = relation(n, 11);
        let c2 = relation(n, 12);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let cfg = LrGwConfig { landmarks: 8, outer_iters: 15, ..Default::default() };
        let r = lr_gw_factored(&p, GroundCost::L2, &cfg);
        assert!(r.value.is_finite(), "value {}", r.value);
        assert!(r.plan.is_finite());
        // Projection keeps the factors feasible regardless of the
        // operator approximation quality.
        let rows = r.plan.row_sums();
        for i in 0..n {
            assert!((rows[i] - a[i]).abs() < 1e-4, "row {i}: {}", rows[i]);
        }
    }
}
