//! LR-GW — Linear-time Gromov-Wasserstein with low-rank couplings
//! (Scetbon, Peyré & Cuturi 2022), the "quadratic approach" variant used
//! as a comparator in §6.1.
//!
//! The coupling is constrained to `T = Q diag(1/g) Rᵀ` with
//! `Q ∈ Π(a, g)`, `R ∈ Π(b, g)`, `g ∈ Δ^{r−1}` (rank r, paper setting
//! r = ⌈n/20⌉). We implement a simplified mirror-descent scheme:
//! at each step the GW gradient `∇ = C(T)` is formed through the
//! decomposable factorization (ℓ2 only — matching the paper, which omits
//! LR-GW from the ℓ1 experiments), the factors take a multiplicative
//! (exponentiated-gradient) step, and each factor is re-projected onto its
//! transport polytope by Sinkhorn. This is a *documented simplified
//! reimplementation*: no kernel low-rank factorization of (Cx, Cy) and no
//! adaptive step sizes, so the asymptotic constant is worse than the
//! original, but the coupling manifold, objective, and update structure
//! match, which is what the accuracy comparisons exercise.

use std::time::Instant;

use super::core::Workspace;
use super::cost::GroundCost;
use super::solver::{GwSolver, Opts, PhaseTimings, Plan, SolveReport, SolverBase};
use super::{DenseGwResult, GwProblem};
use crate::ensure;
use crate::linalg::Mat;
use crate::ot::sinkhorn;
use crate::rng::Rng;
use crate::util::error::Result;

/// Configuration for LR-GW.
#[derive(Clone, Copy, Debug)]
pub struct LrGwConfig {
    /// Coupling rank r (0 → ⌈n/20⌉, the paper's setting).
    pub rank: usize,
    /// Mirror-descent step size γ.
    pub step: f64,
    /// Outer iterations.
    pub outer_iters: usize,
    /// Sinkhorn iterations per factor projection.
    pub proj_iters: usize,
}

impl Default for LrGwConfig {
    fn default() -> Self {
        LrGwConfig { rank: 0, step: 1.0, outer_iters: 30, proj_iters: 50 }
    }
}

/// Reconstruct the dense coupling `T = Q diag(1/g) Rᵀ` (for evaluation).
fn reconstruct(q: &Mat, r: &Mat, g: &[f64]) -> Mat {
    let m = q.rows();
    let n = r.rows();
    let rank = g.len();
    let mut t = Mat::zeros(m, n);
    for i in 0..m {
        let qrow = q.row(i);
        let trow = t.row_mut(i);
        for j in 0..n {
            let rrow = r.row(j);
            let mut s = 0.0;
            for k in 0..rank {
                s += qrow[k] * rrow[k] / g[k].max(1e-300);
            }
            trow[j] = s;
        }
    }
    t
}

/// Run LR-GW. Only decomposable costs are supported (the paper runs LR-GW
/// with ℓ2 only); panics on ℓ1.
pub fn lr_gw(p: &GwProblem, cost: GroundCost, cfg: &LrGwConfig) -> DenseGwResult {
    let d = cost
        .decomposition()
        .expect("LR-GW requires a decomposable ground cost (paper: ℓ2 only)");
    let (m, n) = (p.m(), p.n());
    let rank = if cfg.rank == 0 { n.div_ceil(20).max(2) } else { cfg.rank.max(2) };

    // Initialize: g uniform, Q = a gᵀ, R = b gᵀ (independent couplings).
    let g: Vec<f64> = vec![1.0 / rank as f64; rank];
    let mut q = Mat::outer(p.a, &g);
    let mut r = Mat::outer(p.b, &g);
    let mut g = g;

    // Precompute the decomposable pieces.
    let f1cx = p.cx.map(d.f1);
    let f2cy = p.cy.map(d.f2);
    let h1cx = p.cx.map(d.h1);
    let h2cy = p.cy.map(d.h2);
    let h2cy_t = h2cy.transpose();

    let mut outer = 0;
    for _ in 0..cfg.outer_iters {
        // C(T) via the factorization: T = Q diag(1/g) Rᵀ.
        // h1(Cx)·T·h2(Cy)ᵀ = [h1(Cx)·Q] diag(1/g) [h2(Cy)·R]ᵀ — O(n²r).
        let hq = h1cx.matmul(&q); // m×r
        let hr = h2cy_t.transpose().matmul(&r); // n×r  (h2(Cy)·R)
        let row_marg = q.row_sums(); // = T1 (since R ∈ Π(b,g) sums columns to g)
        let col_marg = r.row_sums();
        let term1 = f1cx.matvec(&row_marg);
        let term2 = f2cy.matvec(&col_marg);
        // grad[i][j] = term1[i] + term2[j] − Σ_k hq[i,k] hr[j,k]/g[k]
        let mut grad = Mat::zeros(m, n);
        for i in 0..m {
            let hqi = hq.row(i);
            let grow = grad.row_mut(i);
            for j in 0..n {
                let hrj = hr.row(j);
                let mut s = 0.0;
                for k in 0..rank {
                    s += hqi[k] * hrj[k] / g[k].max(1e-300);
                }
                grow[j] = term1[i] + term2[j] - s;
            }
        }
        // Factor gradients: ∇Q = grad · R diag(1/g); ∇R = gradᵀ · Q diag(1/g);
        // ∇g_k = −(Qᵀ grad R)_kk / g_k².
        let mut r_scaled = r.clone();
        for j in 0..n {
            let row = r_scaled.row_mut(j);
            for k in 0..rank {
                row[k] /= g[k].max(1e-300);
            }
        }
        let grad_q = grad.matmul(&r_scaled); // m×r
        let grad_r = grad.transpose().matmul(&{
            let mut qs = q.clone();
            for i in 0..m {
                let row = qs.row_mut(i);
                for k in 0..rank {
                    row[k] /= g[k].max(1e-300);
                }
            }
            qs
        }); // n×r
        let qtgr = q.transpose().matmul(&grad).matmul(&r); // r×r
        let grad_g: Vec<f64> = (0..rank)
            .map(|k| -qtgr[(k, k)] / (g[k] * g[k]).max(1e-300))
            .collect();

        // Mirror (multiplicative) steps with normalization-stabilized rates.
        let scale_q = cfg.step / (1.0 + grad_q.max_abs());
        let mut q_new = Mat::zeros(m, rank);
        for i in 0..m {
            let (qrow, grow) = (q.row(i), grad_q.row(i));
            let nrow = q_new.row_mut(i);
            for k in 0..rank {
                nrow[k] = (qrow[k].max(1e-300)) * (-scale_q * grow[k]).exp();
            }
        }
        let scale_r = cfg.step / (1.0 + grad_r.max_abs());
        let mut r_new = Mat::zeros(n, rank);
        for j in 0..n {
            let (rrow, grow) = (r.row(j), grad_r.row(j));
            let nrow = r_new.row_mut(j);
            for k in 0..rank {
                nrow[k] = (rrow[k].max(1e-300)) * (-scale_r * grow[k]).exp();
            }
        }
        let g_absmax = grad_g.iter().fold(0.0f64, |mx, &x| mx.max(x.abs()));
        let scale_g = cfg.step / (1.0 + g_absmax);
        let mut g_new: Vec<f64> = g
            .iter()
            .zip(&grad_g)
            .map(|(&gk, &dk)| gk.max(1e-300) * (-scale_g * dk).exp())
            .collect();
        crate::util::normalize(&mut g_new);
        g = g_new;

        // Project factors back onto their polytopes: Q ∈ Π(a, g), R ∈ Π(b, g).
        q = sinkhorn(p.a, &g, &q_new, cfg.proj_iters, 0.0).plan;
        r = sinkhorn(p.b, &g, &r_new, cfg.proj_iters, 0.0).plan;
        outer += 1;
    }

    let t = reconstruct(&q, &r, &g);
    let value = super::tensor::tensor_product(p.cx, p.cy, &t, cost).frob_inner(&t);
    DenseGwResult { value, plan: t, outer_iters: outer, converged: false }
}

/// Registry solver for LR-GW (`"lr_gw"`). Deterministic mirror descent;
/// requires a decomposable ground cost (the registry path reports a
/// descriptive error on ℓ1 instead of the free function's panic). The
/// mirror-descent schedule keeps its own defaults (rank ⌈n/20⌉, 30 outer
/// steps) rather than inheriting the Sinkhorn-style base caps; override
/// via `rank=` / `step=` / `outer=` / `proj=` options.
pub struct LrGwSolver {
    /// Ground cost `L` (must be decomposable).
    pub cost: GroundCost,
    /// LR-GW parameters.
    pub cfg: LrGwConfig,
}

impl LrGwSolver {
    pub(crate) fn from_opts(base: &SolverBase, o: &mut Opts) -> Result<Self> {
        o.precision_f64_only("lr_gw", base.precision)?;
        let d = LrGwConfig::default();
        Ok(LrGwSolver {
            cost: o.cost(base.cost)?,
            cfg: LrGwConfig {
                rank: o.usize("rank", d.rank)?,
                step: o.f64("step", d.step)?,
                outer_iters: o.usize("outer", d.outer_iters)?,
                proj_iters: o.usize("proj", d.proj_iters)?,
            },
        })
    }
}

impl GwSolver for LrGwSolver {
    fn name(&self) -> &'static str {
        "lr_gw"
    }

    fn solve(&self, p: &GwProblem, _rng: &mut Rng, _ws: &mut Workspace) -> Result<SolveReport> {
        ensure!(
            self.cost.is_decomposable(),
            "lr_gw requires a decomposable ground cost (l2 or kl), got {}",
            self.cost.name()
        );
        let t0 = Instant::now();
        let r = lr_gw(p, self.cost, &self.cfg);
        Ok(SolveReport {
            solver: self.name(),
            value: r.value,
            plan: Plan::Dense(r.plan),
            outer_iters: r.outer_iters,
            converged: r.converged,
            timings: PhaseTimings {
                sample_seconds: 0.0,
                solve_seconds: t0.elapsed().as_secs_f64(),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::util::uniform;

    fn relation(n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let pts: Vec<[f64; 2]> = (0..n).map(|_| [rng.f64(), rng.f64()]).collect();
        Mat::from_fn(n, n, |i, j| crate::linalg::sqdist(&pts[i], &pts[j]).sqrt())
    }

    #[test]
    fn coupling_is_feasible() {
        let n = 12;
        let c1 = relation(n, 1);
        let c2 = relation(n, 2);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let r = lr_gw(&p, GroundCost::L2, &LrGwConfig::default());
        let rows = r.plan.row_sums();
        let cols = r.plan.col_sums();
        for i in 0..n {
            assert!((rows[i] - a[i]).abs() < 1e-4, "row {i}: {}", rows[i]);
            assert!((cols[i] - a[i]).abs() < 1e-4, "col {i}: {}", cols[i]);
        }
    }

    #[test]
    fn improves_over_naive_plan() {
        let n = 14;
        let c1 = relation(n, 3);
        let mut c2 = relation(n, 3); // same space, perturbed
        for i in 0..n {
            for j in 0..n {
                c2[(i, j)] *= 1.02;
            }
        }
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let naive = super::super::tensor::gw_energy(&c1, &c2, &Mat::outer(&a, &a), GroundCost::L2);
        let r = lr_gw(&p, GroundCost::L2, &LrGwConfig { outer_iters: 40, ..Default::default() });
        assert!(r.value <= naive + 1e-9, "lr {} vs naive {naive}", r.value);
    }

    #[test]
    #[should_panic(expected = "decomposable")]
    fn rejects_l1() {
        let n = 5;
        let c = relation(n, 4);
        let a = uniform(n);
        let p = GwProblem::new(&c, &c, &a, &a);
        lr_gw(&p, GroundCost::L1, &LrGwConfig::default());
    }

    #[test]
    fn plan_has_low_rank_structure() {
        // Rank-r coupling: the reconstruction T = Q diag(1/g) Rᵀ has rank
        // ≤ r. Verify via the Jacobi eigenvalues of TᵀT (≤ r non-zeros).
        let n = 10;
        let c1 = relation(n, 5);
        let c2 = relation(n, 6);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let rank = 3;
        let r = lr_gw(&p, GroundCost::L2, &LrGwConfig { rank, outer_iters: 10, ..Default::default() });
        let tt = r.plan.transpose().matmul(&r.plan);
        let eig = crate::linalg::symmetric_eigen(&tt, 60);
        let nonzero = eig.values.iter().filter(|&&l| l > 1e-12).count();
        assert!(nonzero <= rank, "rank {nonzero} > {rank}");
    }
}
