//! Unbalanced Gromov-Wasserstein distance (Séjourné et al. 2021) — §5.1.
//!
//! `UGW = min_{T ≥ 0} ⟨L(Cx,Cy)⊗T, T⟩ + λ KL⊗(T1‖a) + λ KL⊗(Tᵀ1‖b)`
//!
//! with the quadratic KL `KL⊗(μ‖ν) = KL(μ⊗μ‖ν⊗ν) = 2m(μ)KL(μ‖ν) +
//! (m(μ)−m(ν))²` (generalized KL). Dense solvers: EUGW (entropic kernel)
//! and PGA-UGW (Bregman-proximal kernel, Eq. (8)), both using unbalanced
//! Sinkhorn with exponent λ̄/(λ̄+ε̄) and the mass-rescaling step.

use super::cost::GroundCost;
use super::tensor::tensor_product;
use super::{GwProblem, Regularizer};
use crate::linalg::Mat;
use crate::ot::unbalanced_sinkhorn;
use crate::util::kl_div;

/// Configuration for the unbalanced solvers.
#[derive(Clone, Copy, Debug)]
pub struct UgwConfig {
    /// Marginal relaxation weight λ.
    pub lambda: f64,
    /// Regularization weight ε.
    pub epsilon: f64,
    /// Outer iterations R.
    pub outer_iters: usize,
    /// Inner unbalanced-Sinkhorn iterations H.
    pub inner_iters: usize,
    /// Outer stopping tolerance on ‖ΔT‖_F (0 disables).
    pub tol: f64,
}

impl Default for UgwConfig {
    fn default() -> Self {
        UgwConfig { lambda: 1.0, epsilon: 0.01, outer_iters: 20, inner_iters: 50, tol: 1e-9 }
    }
}

/// Result of a dense UGW solve.
pub struct UgwResult {
    /// The UGW objective at the final plan.
    pub value: f64,
    /// Final (unnormalized) coupling.
    pub plan: Mat,
    /// Outer iterations performed.
    pub outer_iters: usize,
}

/// Quadratic KL: `KL⊗(μ‖ν) = 2 m(μ) KL(μ‖ν) + (m(μ) − m(ν))²`.
pub fn kl_otimes(mu: &[f64], nu: &[f64]) -> f64 {
    let m_mu: f64 = mu.iter().sum();
    let m_nu: f64 = nu.iter().sum();
    2.0 * m_mu * kl_div(mu, nu) + (m_mu - m_nu) * (m_mu - m_nu)
}

/// The scalar `E(T)` term of the unbalanced cost `C_un(T)` (§5.1):
/// `E(T) = λ Σ_i log(r_i/a_i) r_i + λ Σ_j log(c_j/b_j) c_j`
/// with `r = T1`, `c = Tᵀ1` (0·log 0 := 0).
pub fn unbalanced_cost_shift(
    row_sums: &[f64],
    col_sums: &[f64],
    a: &[f64],
    b: &[f64],
    lambda: f64,
) -> f64 {
    let mut e = 0.0;
    for (&r, &ai) in row_sums.iter().zip(a) {
        if r > 0.0 {
            e += (r / ai.max(1e-300)).ln() * r;
        }
    }
    for (&c, &bj) in col_sums.iter().zip(b) {
        if c > 0.0 {
            e += (c / bj.max(1e-300)).ln() * c;
        }
    }
    lambda * e
}

/// The full UGW objective at a plan.
pub fn ugw_objective(p: &GwProblem, t: &Mat, cost: GroundCost, lambda: f64) -> f64 {
    let quad = tensor_product(p.cx, p.cy, t, cost).frob_inner(t);
    let r = t.row_sums();
    let c = t.col_sums();
    quad + lambda * kl_otimes(&r, p.a) + lambda * kl_otimes(&c, p.b)
}

/// Shared dense UGW loop. `reg` picks the kernel:
/// Proximal — `K = exp(−C_un/ε̄) ⊙ T⁽ʳ⁾` (Eq. 8, PGA-UGW);
/// Entropy  — `K = exp(−C_un/ε̄)` (EUGW).
fn ugw_loop(p: &GwProblem, cost: GroundCost, reg: Regularizer, cfg: &UgwConfig) -> UgwResult {
    let (m, n) = (p.m(), p.n());
    let ma: f64 = p.a.iter().sum();
    let mb: f64 = p.b.iter().sum();
    // T⁽⁰⁾ = a bᵀ / √(m(a)m(b)).
    let mut t = Mat::outer(p.a, p.b);
    t.scale(1.0 / (ma * mb).sqrt());
    let mut outer = 0;
    for _ in 0..cfg.outer_iters {
        let mass = t.sum();
        if mass <= 0.0 || !mass.is_finite() {
            break;
        }
        let eps_bar = cfg.epsilon * mass;
        let lam_bar = cfg.lambda * mass;
        // C_un(T) = L⊗T + E(T)·1 (scalar shift).
        let c = tensor_product(p.cx, p.cy, &t, cost);
        let shift =
            unbalanced_cost_shift(&t.row_sums(), &t.col_sums(), p.a, p.b, cfg.lambda);
        let mut k = Mat::zeros(m, n);
        for i in 0..m {
            let crow = c.row(i);
            let trow = t.row(i);
            let krow = k.row_mut(i);
            for j in 0..n {
                let e = (-(crow[j] + shift) / eps_bar).exp();
                krow[j] = match reg {
                    Regularizer::Proximal => e * trow[j],
                    Regularizer::Entropy => e,
                };
            }
        }
        let mut t_next = unbalanced_sinkhorn(p.a, p.b, &k, lam_bar, eps_bar, cfg.inner_iters);
        // Step 10: mass rescaling √(m(T⁽ʳ⁾)/m(T⁽ʳ⁺¹⁾)).
        let next_mass = t_next.sum();
        if !next_mass.is_finite() || next_mass <= 0.0 {
            // Kernel over/underflow (extreme λ/ε): keep the last good plan.
            break;
        }
        t_next.scale((mass / next_mass).sqrt());
        outer += 1;
        if cfg.tol > 0.0 {
            let mut diff = 0.0;
            for (x, y) in t_next.data().iter().zip(t.data()) {
                let d = x - y;
                diff += d * d;
            }
            t = t_next;
            if diff.sqrt() < cfg.tol {
                break;
            }
        } else {
            t = t_next;
        }
    }
    let value = ugw_objective(p, &t, cost, cfg.lambda);
    UgwResult { value, plan: t, outer_iters: outer }
}

/// Entropic UGW (Séjourné et al. 2021 style alternating scheme).
pub fn eugw(p: &GwProblem, cost: GroundCost, cfg: &UgwConfig) -> UgwResult {
    ugw_loop(p, cost, Regularizer::Entropy, cfg)
}

/// Proximal-gradient UGW — the accuracy benchmark of Fig. 3.
pub fn pga_ugw(p: &GwProblem, cost: GroundCost, cfg: &UgwConfig) -> UgwResult {
    ugw_loop(p, cost, Regularizer::Proximal, cfg)
}

/// Naive baseline: `T = a bᵀ / √(m(a) m(b))` evaluated on the UGW objective.
pub fn naive_ugw(p: &GwProblem, cost: GroundCost, lambda: f64) -> f64 {
    let ma: f64 = p.a.iter().sum();
    let mb: f64 = p.b.iter().sum();
    let mut t = Mat::outer(p.a, p.b);
    t.scale(1.0 / (ma * mb).sqrt());
    ugw_objective(p, &t, cost, lambda)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::util::uniform;

    fn relation(n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let pts: Vec<[f64; 2]> = (0..n).map(|_| [rng.f64(), rng.f64()]).collect();
        Mat::from_fn(n, n, |i, j| crate::linalg::sqdist(&pts[i], &pts[j]).sqrt())
    }

    #[test]
    fn kl_otimes_zero_iff_equal() {
        let mu = vec![0.3, 0.7];
        assert!(kl_otimes(&mu, &mu).abs() < 1e-12);
        assert!(kl_otimes(&mu, &[0.5, 0.5]) > 0.0);
    }

    #[test]
    fn kl_otimes_matches_definition() {
        // Direct tensor-product computation on a small case.
        let mu = [0.2f64, 0.5];
        let nu = [0.4f64, 0.3];
        let mut direct = 0.0;
        for i in 0..2 {
            for j in 0..2 {
                let p = mu[i] * mu[j];
                let q = nu[i] * nu[j];
                direct += p * (p / q).ln() - p + q;
            }
        }
        assert!(
            (kl_otimes(&mu, &nu) - direct).abs() < 1e-12,
            "{} vs {direct}",
            kl_otimes(&mu, &nu)
        );
    }

    #[test]
    fn identical_spaces_small_value() {
        let n = 8;
        let c = relation(n, 1);
        let a = uniform(n);
        let p = GwProblem::new(&c, &c, &a, &a);
        let cfg = UgwConfig { lambda: 1.0, epsilon: 0.005, outer_iters: 40, inner_iters: 80, tol: 1e-10 };
        let r = pga_ugw(&p, GroundCost::L2, &cfg);
        // The quadratic term vanishes at the optimum; marginal penalties are
        // small because the optimum is near-balanced here.
        assert!(r.value < 0.05, "UGW = {}", r.value);
    }

    #[test]
    fn optimized_beats_naive() {
        let c1 = relation(8, 2);
        let c2 = relation(8, 3);
        let a = uniform(8);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let cfg = UgwConfig::default();
        let r = pga_ugw(&p, GroundCost::L2, &cfg);
        let naive = naive_ugw(&p, GroundCost::L2, cfg.lambda);
        assert!(r.value <= naive + 1e-6, "opt {} vs naive {naive}", r.value);
    }

    #[test]
    fn handles_unbalanced_masses() {
        // a has total mass 1, b has mass 1.5.
        let c1 = relation(6, 4);
        let c2 = relation(6, 5);
        let a = uniform(6);
        let b: Vec<f64> = vec![0.25; 6];
        let p = GwProblem::new(&c1, &c2, &a, &b);
        let cfg = UgwConfig::default();
        let r = eugw(&p, GroundCost::L2, &cfg);
        assert!(r.value.is_finite());
        assert!(r.plan.sum() > 0.0);
    }

    #[test]
    fn large_lambda_matches_balanced_gw() {
        // λ → ∞ forces the marginals ⇒ quadratic term ≈ balanced GW value.
        let c1 = relation(7, 6);
        let c2 = relation(7, 7);
        let a = uniform(7);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let cfg = UgwConfig { lambda: 1e4, epsilon: 0.01, outer_iters: 40, inner_iters: 100, tol: 1e-11 };
        let r = pga_ugw(&p, GroundCost::L2, &cfg);
        let quad = tensor_product(&c1, &c2, &r.plan, GroundCost::L2).frob_inner(&r.plan);
        let balanced = super::super::alg1::pga_gw(
            &p,
            GroundCost::L2,
            &super::super::alg1::Alg1Config { epsilon: 0.01, outer_iters: 40, inner_iters: 100, tol: 1e-11 },
        );
        let denom = balanced.value.max(1e-6);
        assert!(
            (quad - balanced.value).abs() / denom < 0.3,
            "ugw quad {quad} vs gw {}",
            balanced.value
        );
    }
}
