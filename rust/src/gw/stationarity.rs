//! The stationarity gap `G(T)` of §4:
//!
//!   `G(T) = E(T, T) − min_{T' ∈ Π(a,b)} E(T, T')`
//!
//! where `E(T, T') = Σ L(Cx,Cy) T T'` and `T` is a stationary point of the
//! GW energy iff `G(T) = 0` (Reddi et al. 2016). The inner minimum is a
//! plain (linear) OT problem with cost `∇E(T)/2 = L(Cx,Cy) ⊗ T`, solved
//! exactly by the transportation simplex. Used by the theory-validation
//! bench for Theorem 1 / Corollary 1.

use super::cost::GroundCost;
use super::tensor::tensor_product;
use super::GwProblem;
use crate::linalg::Mat;
use crate::ot::emd;

/// Compute `G(T)` exactly (up to the LP solver's tolerance).
pub fn stationarity_gap(p: &GwProblem, t: &Mat, cost: GroundCost) -> f64 {
    let c = tensor_product(p.cx, p.cy, t, cost);
    let e_tt = c.frob_inner(t);
    let best = emd(p.a, p.b, &c);
    e_tt - best.cost
}

/// Convenience: gap for a sparse plan (densified first).
pub fn stationarity_gap_sparse(
    p: &GwProblem,
    t: &crate::sparse::Coo,
    cost: GroundCost,
) -> f64 {
    stationarity_gap(p, &t.to_dense(), cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gw::alg1::{pga_gw, Alg1Config};
    use crate::rng::Xoshiro256;
    use crate::util::uniform;

    fn relation(n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let pts: Vec<[f64; 2]> = (0..n).map(|_| [rng.f64(), rng.f64()]).collect();
        Mat::from_fn(n, n, |i, j| {
            let dx = pts[i][0] - pts[j][0];
            let dy = pts[i][1] - pts[j][1];
            (dx * dx + dy * dy).sqrt()
        })
    }

    #[test]
    fn gap_nonnegative() {
        let n = 8;
        let c1 = relation(n, 1);
        let c2 = relation(n, 2);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let t = Mat::outer(&a, &a);
        let g = stationarity_gap(&p, &t, GroundCost::L2);
        assert!(g >= -1e-9, "gap {g}");
    }

    #[test]
    fn gap_shrinks_after_optimization() {
        let n = 10;
        let c1 = relation(n, 3);
        let c2 = relation(n, 4);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let t0 = Mat::outer(&a, &a);
        let g0 = stationarity_gap(&p, &t0, GroundCost::L2);
        let cfg = Alg1Config { epsilon: 0.005, outer_iters: 60, inner_iters: 100, tol: 1e-11 };
        let r = pga_gw(&p, GroundCost::L2, &cfg);
        let g1 = stationarity_gap(&p, &r.plan, GroundCost::L2);
        assert!(
            g1 < g0 * 0.5,
            "gap did not shrink: initial {g0}, after optimization {g1}"
        );
    }
}
