//! **Algorithm 3 — Spar-UGW**: importance sparsification for the
//! unbalanced GW distance (§5.2).
//!
//! Differences from Algorithm 2:
//! * sampling probability (9):
//!   `p_ij ∝ (a_i b_j)^{λ/(2λ+ε)} · K_ij^{ε/(2λ+ε)}`, with `K` built once
//!   from the initial plan `T̃⁽⁰⁾ = a bᵀ/√(m(a)m(b))` — O(mn) when `L` is
//!   decomposable (T⁽⁰⁾ is rank one), O(m²n²) otherwise;
//! * the cost gains the scalar shift `E(T̃)` and the inner solver is the
//!   *unbalanced* sparse Sinkhorn with exponent λ̄/(λ̄+ε̄);
//! * the mass-rescaling step 10.
//!
//! Since the SparCore refactor this file keeps only the Eq. (9) sampler
//! and thin adapters over [`super::core`] with the [`Unbalanced`] marginal
//! strategy; outputs are bit-identical to the historical implementation.

use std::time::Instant;

use super::core::{Engine, Unbalanced, Workspace};
use super::cost::GroundCost;
use super::sampling::SampledSet;
use super::solver::{GwSolver, Opts, PhaseTimings, Plan, SolveReport, SolverBase};
use super::tensor::{tensor_product, SparseCostContext};
use super::ugw::{unbalanced_cost_shift, UgwConfig};
use super::GwProblem;
use crate::kernel::Precision;
use crate::linalg::Mat;
use crate::rng::{AliasTable, Rng};
use crate::sparse::Coo;
use crate::util::error::Result;

/// Configuration for Spar-UGW.
#[derive(Clone, Copy, Debug)]
pub struct SparUgwConfig {
    /// The shared UGW parameters (λ, ε, R, H, tol).
    pub ugw: UgwConfig,
    /// Number of sampled elements s (0 → 16·max(m,n)).
    pub sample_size: usize,
    /// Shrinkage toward uniform sampling (condition H.4 analogue).
    pub shrink: f64,
}

impl Default for SparUgwConfig {
    fn default() -> Self {
        SparUgwConfig { ugw: UgwConfig::default(), sample_size: 0, shrink: 0.0 }
    }
}

/// Result of a Spar-UGW solve.
pub struct SparUgwResult {
    /// The estimate ÛGW (step 11).
    pub value: f64,
    /// Sparse coupling on the sampled pattern.
    pub plan: Coo,
    /// Outer iterations performed.
    pub outer_iters: usize,
    /// True if the ‖ΔT̃‖_F tolerance was reached before the iteration cap.
    pub converged: bool,
    /// Support size |S|.
    pub support: usize,
}

/// Build the sampling probabilities of Eq. (9) and draw the index set.
/// Steps 2–5 of Algorithm 3. Public so external harnesses (tests, the
/// theory benches) can fix the set and drive [`spar_ugw_with_set`]
/// deterministically.
pub fn sample_ugw_set(
    p: &GwProblem,
    cost: GroundCost,
    cfg: &SparUgwConfig,
    rng: &mut Rng,
) -> SampledSet {
    let (m, n) = (p.m(), p.n());
    let s = if cfg.sample_size == 0 { 16 * m.max(n) } else { cfg.sample_size };
    let ma: f64 = p.a.iter().sum();
    let mb: f64 = p.b.iter().sum();
    // T̃⁽⁰⁾ and its kernel (step 3).
    let mut t0 = Mat::outer(p.a, p.b);
    t0.scale(1.0 / (ma * mb).sqrt());
    let mass0 = t0.sum();
    let eps_bar = cfg.ugw.epsilon * mass0;
    let c0 = tensor_product(p.cx, p.cy, &t0, cost);
    let shift = unbalanced_cost_shift(&t0.row_sums(), &t0.col_sums(), p.a, p.b, cfg.ugw.lambda);

    // Probability weights (9): (a_i b_j)^{λ/(2λ+ε)} K_ij^{ε/(2λ+ε)}.
    let lam = cfg.ugw.lambda;
    let eps = cfg.ugw.epsilon;
    let e1 = lam / (2.0 * lam + eps);
    let e2 = eps / (2.0 * lam + eps);
    let mut weights = Vec::with_capacity(m * n);
    for i in 0..m {
        let c_row = c0.row(i);
        let t_row = t0.row(i);
        for j in 0..n {
            let k_ij = (-(c_row[j] + shift) / eps_bar).exp() * t_row[j];
            let w = (p.a[i] * p.b[j]).max(0.0).powf(e1) * k_ij.max(0.0).powf(e2);
            weights.push(w);
        }
    }
    // Shrinkage toward uniform keeps all probabilities bounded below.
    if cfg.shrink > 0.0 {
        let total: f64 = weights.iter().sum();
        let unif = total / (m * n) as f64;
        for w in &mut weights {
            *w = (1.0 - cfg.shrink) * *w + cfg.shrink * unif;
        }
    }
    // Degenerate fallback: all-zero weights ⇒ uniform.
    if weights.iter().sum::<f64>() <= 0.0 {
        weights.iter_mut().for_each(|w| *w = 1.0);
    }

    let alias = AliasTable::new(&weights);
    let draws = alias.sample_many(rng, s);
    let mut keys: Vec<usize> = draws;
    keys.sort_unstable();
    keys.dedup();
    let mut rows = Vec::with_capacity(keys.len());
    let mut cols = Vec::with_capacity(keys.len());
    let mut wts = Vec::with_capacity(keys.len());
    for key in keys {
        let (i, j) = (key / n, key % n);
        rows.push(i);
        cols.push(j);
        wts.push((s as f64 * alias.prob_of(key)).min(1.0));
    }
    SampledSet { rows, cols, weights: wts, budget: s }
}

/// Run Algorithm 3.
pub fn spar_ugw(
    p: &GwProblem,
    cost: GroundCost,
    cfg: &SparUgwConfig,
    rng: &mut Rng,
) -> SparUgwResult {
    let set = sample_ugw_set(p, cost, cfg, rng);
    spar_ugw_with_set(p, cost, cfg, &set)
}

/// Algorithm 3 with an externally supplied index set. Allocates a fresh
/// [`Workspace`]; batch callers should use [`spar_ugw_with_workspace`].
pub fn spar_ugw_with_set(
    p: &GwProblem,
    cost: GroundCost,
    cfg: &SparUgwConfig,
    set: &SampledSet,
) -> SparUgwResult {
    let mut ws = Workspace::new();
    spar_ugw_with_workspace(p, cost, cfg, set, &mut ws)
}

/// Algorithm 3 on the shared [`SparCore` engine](super::core): steps 6–11
/// are the [`Engine`] outer loop with the [`Unbalanced`] marginal strategy
/// (mass-dependent ε̄/λ̄, the `E(T̃)` cost shift, the λ̄/(λ̄+ε̄) inner solver,
/// the mass-rescaling step and the KL⊗-penalized objective).
pub fn spar_ugw_with_workspace(
    p: &GwProblem,
    cost: GroundCost,
    cfg: &SparUgwConfig,
    set: &SampledSet,
    ws: &mut Workspace,
) -> SparUgwResult {
    let ctx = SparseCostContext::new(p.cx, p.cy, &set.rows, &set.cols, cost);
    let eng = Engine {
        a: p.a,
        b: p.b,
        a64: p.a,
        b64: p.b,
        set,
        ctx: &ctx,
        outer_iters: cfg.ugw.outer_iters,
        tol: cfg.ugw.tol,
    };
    let mut strategy =
        Unbalanced::new(cfg.ugw.lambda, cfg.ugw.epsilon, cfg.ugw.inner_iters, p.a, p.b);
    let r = eng.solve(&mut strategy, ws);
    SparUgwResult {
        value: r.value,
        plan: r.plan,
        outer_iters: r.outer_iters,
        converged: r.converged,
        support: r.support,
    }
}

/// [`spar_ugw_with_workspace`] in mixed precision: the kernel build and
/// the unbalanced inner solver run in f32 on the workspace's
/// [`lane32`](Workspace::lane32); the mass terms, `E(T̃)` shift, KL⊗
/// objective and returned plan stay f64. The Eq. (9) sampling step is
/// O(mn) preprocessing and always runs in f64 (see `sample_ugw_set`).
pub fn spar_ugw_with_workspace_f32(
    p: &GwProblem,
    cost: GroundCost,
    cfg: &SparUgwConfig,
    set: &SampledSet,
    ws: &mut Workspace,
) -> SparUgwResult {
    let ctx = SparseCostContext::new(p.cx, p.cy, &set.rows, &set.cols, cost);
    let a32: Vec<f32> = p.a.iter().map(|&x| x as f32).collect();
    let b32: Vec<f32> = p.b.iter().map(|&x| x as f32).collect();
    let eng = Engine {
        a: &a32,
        b: &b32,
        a64: p.a,
        b64: p.b,
        set,
        ctx: &ctx,
        outer_iters: cfg.ugw.outer_iters,
        tol: cfg.ugw.tol,
    };
    let mut strategy =
        Unbalanced::new(cfg.ugw.lambda, cfg.ugw.epsilon, cfg.ugw.inner_iters, p.a, p.b);
    let r = eng.solve(&mut strategy, ws.lane32());
    SparUgwResult {
        value: r.value,
        plan: r.plan,
        outer_iters: r.outer_iters,
        converged: r.converged,
        support: r.support,
    }
}

/// Registry solver for Algorithm 3 (`"spar_ugw"`): the Eq. (9) sampler on
/// the caller's RNG, then the SparCore engine with the [`Unbalanced`]
/// strategy on the caller's workspace. Structure-only (no fused variant).
pub struct SparUgwSolver {
    /// Ground cost `L`.
    pub cost: GroundCost,
    /// Algorithm-3 parameters.
    pub cfg: SparUgwConfig,
    /// Kernel precision for the engine loop (`f64` default; `f32` runs
    /// the kernel build and inner solver at half width). The Eq. (9)
    /// sampler is dense O(mn) preprocessing and stays f64 either way.
    pub precision: Precision,
}

impl SparUgwSolver {
    pub(crate) fn from_opts(base: &SolverBase, o: &mut Opts) -> Result<Self> {
        Ok(SparUgwSolver {
            cost: o.cost(base.cost)?,
            cfg: SparUgwConfig {
                ugw: UgwConfig {
                    lambda: o.f64("lambda", base.lambda)?,
                    epsilon: o.f64("epsilon", base.epsilon)?,
                    outer_iters: o.usize("outer", base.outer_iters)?,
                    inner_iters: o.usize("inner", base.inner_iters)?,
                    tol: o.f64("tol", base.tol)?,
                },
                sample_size: o.usize("s", base.sample_size)?,
                shrink: o.f64("shrink", base.shrink)?,
            },
            precision: o.precision(base.precision)?,
        })
    }
}

impl GwSolver for SparUgwSolver {
    fn name(&self) -> &'static str {
        "spar_ugw"
    }

    fn solve(&self, p: &GwProblem, rng: &mut Rng, ws: &mut Workspace) -> Result<SolveReport> {
        let t0 = Instant::now();
        let set = sample_ugw_set(p, self.cost, &self.cfg, rng);
        let sample_seconds = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let r = match self.precision {
            Precision::F64 => spar_ugw_with_workspace(p, self.cost, &self.cfg, &set, ws),
            Precision::F32 => spar_ugw_with_workspace_f32(p, self.cost, &self.cfg, &set, ws),
        };
        Ok(SolveReport {
            solver: self.name(),
            value: r.value,
            plan: Plan::Sparse(r.plan),
            outer_iters: r.outer_iters,
            converged: r.converged,
            timings: PhaseTimings::basic(sample_seconds, t1.elapsed().as_secs_f64()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gw::ugw::{naive_ugw, pga_ugw};
    use crate::rng::Xoshiro256;
    use crate::util::uniform;

    fn relation(n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let pts: Vec<[f64; 2]> = (0..n).map(|_| [rng.f64(), rng.f64()]).collect();
        Mat::from_fn(n, n, |i, j| crate::linalg::sqdist(&pts[i], &pts[j]).sqrt())
    }

    #[test]
    fn runs_and_is_finite() {
        let n = 15;
        let c1 = relation(n, 1);
        let c2 = relation(n, 2);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let mut rng = Xoshiro256::new(3);
        let cfg = SparUgwConfig { sample_size: 16 * n, ..Default::default() };
        let r = spar_ugw(&p, GroundCost::L2, &cfg, &mut rng);
        assert!(r.value.is_finite() && r.value >= -1e-9, "value {}", r.value);
        assert!(r.plan.sum() > 0.0);
    }

    #[test]
    fn close_to_dense_pga_ugw() {
        // Fig. 3 behaviour: the sparse estimate tracks the dense benchmark.
        let n = 20;
        let c1 = relation(n, 4);
        let c2 = relation(n, 5);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let cfg_dense = UgwConfig { lambda: 1.0, epsilon: 0.01, outer_iters: 30, inner_iters: 60, tol: 1e-10 };
        let bench = pga_ugw(&p, GroundCost::L2, &cfg_dense);
        let naive = naive_ugw(&p, GroundCost::L2, 1.0);

        let cfg = SparUgwConfig {
            ugw: cfg_dense,
            sample_size: 20 * n,
            shrink: 0.1,
        };
        let mut rng = Xoshiro256::new(6);
        let mut vals = Vec::new();
        for _ in 0..5 {
            vals.push(spar_ugw(&p, GroundCost::L2, &cfg, &mut rng).value);
        }
        let est = crate::util::mean(&vals);
        // Closer to the benchmark than the naive baseline is.
        let err_spar = (est - bench.value).abs();
        let err_naive = (naive - bench.value).abs();
        assert!(
            err_spar < err_naive,
            "spar err {err_spar} vs naive err {err_naive} (est {est}, bench {})",
            bench.value
        );
    }

    #[test]
    fn unbalanced_masses_supported() {
        let n = 12;
        let c1 = relation(n, 7);
        let c2 = relation(n, 8);
        let a = uniform(n); // mass 1
        let b = vec![2.0 / n as f64; n]; // mass 2
        let p = GwProblem::new(&c1, &c2, &a, &b);
        let mut rng = Xoshiro256::new(9);
        let cfg = SparUgwConfig { sample_size: 12 * n, ..Default::default() };
        let r = spar_ugw(&p, GroundCost::L1, &cfg, &mut rng);
        assert!(r.value.is_finite());
    }

    #[test]
    fn l1_cost_supported() {
        let n = 10;
        let c1 = relation(n, 10);
        let c2 = relation(n, 11);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let mut rng = Xoshiro256::new(12);
        let cfg = SparUgwConfig { sample_size: 12 * n, ..Default::default() };
        let r = spar_ugw(&p, GroundCost::L1, &cfg, &mut rng);
        assert!(r.value.is_finite() && r.value >= -1e-9);
    }
}
