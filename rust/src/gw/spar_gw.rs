//! **Algorithm 2 — Spar-GW**: the paper's main contribution.
//!
//! Instead of the dense O(m²n²) tensor product of Algorithm 1, the coupling
//! and kernel matrices are restricted to a sampled index set `S` of size
//! `s = O(n^{1+δ})`, giving O(mn + s²) total time:
//!
//! 1. build `P` with `p_ij ∝ √(a_i b_j)` (Eq. 5), sample `S` (step 3);
//! 2. per outer iteration, compute the sparse cost
//!    `C̃(T̃)[l] = Σ_{l'∈S} L(Cx, Cy) T̃[l']` in O(s²) (step 6a);
//! 3. exponentiate into the sparse kernel `K̃` with the `1/(s·p_ij)`
//!    importance correction (step 6b);
//! 4. run sparse Sinkhorn in O(Hs) (step 7);
//! 5. output `ĜW = Σ_{S×S} L·T̃·T̃` in O(s²) (step 8).
//!
//! Since the SparCore refactor this file is a thin adapter: the loop body
//! lives in [`super::core`] (shared with Spar-FGW/Spar-UGW), driven here
//! with the [`Balanced`] marginal strategy. Outputs are bit-identical to
//! the historical standalone implementation.

use std::time::Instant;

use super::core::{Balanced, Engine, Workspace};
use super::cost::GroundCost;
use super::fgw::FgwProblem;
use super::sampling::{GwSampler, SampledSet, SideFactors};
use super::solver::{
    GwSolver, Opts, PhaseTimings, Plan, PreparedStructure, SolveReport, SolverBase,
};
use super::tensor::SparseCostContext;
use super::{GwProblem, Regularizer};
use crate::kernel::Precision;
use crate::rng::Rng;
use crate::sparse::Coo;
use crate::util::error::Result;

/// Configuration for Spar-GW (Algorithm 2).
#[derive(Clone, Copy, Debug)]
pub struct SparGwConfig {
    /// Regularization weight ε.
    pub epsilon: f64,
    /// Number of sampled elements s (the paper uses s = 16n by default).
    pub sample_size: usize,
    /// Outer iterations R.
    pub outer_iters: usize,
    /// Inner Sinkhorn iterations H.
    pub inner_iters: usize,
    /// Regularizer (paper default: proximal).
    pub reg: Regularizer,
    /// Shrinkage θ toward uniform sampling (condition H.4). 0 = pure Eq. (5).
    pub shrink: f64,
    /// Outer stopping tolerance on ‖T̃⁽ʳ⁺¹⁾ − T̃⁽ʳ⁾‖_F (0 disables).
    pub tol: f64,
}

impl Default for SparGwConfig {
    fn default() -> Self {
        SparGwConfig {
            epsilon: 0.01,
            sample_size: 0, // 0 -> auto: 16·max(m,n)
            outer_iters: 20,
            inner_iters: 50,
            reg: Regularizer::Proximal,
            shrink: 0.0,
            tol: 1e-9,
        }
    }
}

/// Result of a Spar-GW solve.
pub struct SparGwResult {
    /// The estimate ĜW (step 8).
    pub value: f64,
    /// Sparse coupling on the sampled pattern.
    pub plan: Coo,
    /// Outer iterations performed.
    pub outer_iters: usize,
    /// True if the ‖ΔT̃‖_F tolerance was reached before the iteration cap.
    pub converged: bool,
    /// Number of unique sampled elements |S| (after de-duplication).
    pub support: usize,
}

/// Run Algorithm 2 on a balanced GW problem.
pub fn spar_gw(p: &GwProblem, cost: GroundCost, cfg: &SparGwConfig, rng: &mut Rng) -> SparGwResult {
    let s_budget = if cfg.sample_size == 0 { 16 * p.m().max(p.n()) } else { cfg.sample_size };
    // Steps 2–3: sampling probabilities and index set.
    let sampler = GwSampler::new(p.a, p.b, cfg.shrink);
    let set = sampler.sample_iid(rng, s_budget);
    spar_gw_with_set(p, cost, cfg, &set)
}

/// Algorithm 2 with an externally supplied index set (used by the
/// coordinator, which samples in Rust and feeds the PJRT artifacts, and by
/// the Poisson-sampling theory benches). Allocates a fresh [`Workspace`];
/// batch callers should use [`spar_gw_with_workspace`].
pub fn spar_gw_with_set(
    p: &GwProblem,
    cost: GroundCost,
    cfg: &SparGwConfig,
    set: &SampledSet,
) -> SparGwResult {
    let mut ws = Workspace::new();
    spar_gw_with_workspace(p, cost, cfg, set, &mut ws)
}

/// Algorithm 2 on the shared [`SparCore` engine](super::core): steps 4–8
/// are the [`Engine`] outer loop with the [`Balanced`] marginal strategy.
/// `ws` is reused across calls (the coordinator keeps one per worker).
/// The O(s²) cost kernel and the inner Sinkhorn run on the crate-wide
/// persistent pool (results are identical for every thread count).
pub fn spar_gw_with_workspace(
    p: &GwProblem,
    cost: GroundCost,
    cfg: &SparGwConfig,
    set: &SampledSet,
    ws: &mut Workspace,
) -> SparGwResult {
    // Pre-gather the relation values touched by S (O(s²), once).
    let ctx = SparseCostContext::new(p.cx, p.cy, &set.rows, &set.cols, cost);
    let eng = Engine {
        a: p.a,
        b: p.b,
        a64: p.a,
        b64: p.b,
        set,
        ctx: &ctx,
        outer_iters: cfg.outer_iters,
        tol: cfg.tol,
    };
    let mut strategy =
        Balanced { epsilon: cfg.epsilon, reg: cfg.reg, inner_iters: cfg.inner_iters };
    eng.solve(&mut strategy, ws)
}

/// [`spar_gw_with_workspace`] in mixed precision: the coupling updates,
/// kernel exponentials and inner Sinkhorn run in f32 on the f64
/// workspace's [`lane32`](Workspace::lane32) (reused across solves), while
/// marginal sums, the final ĜW estimate and the returned plan stay f64.
/// On the same sampled set the estimate lands within f32-rounding
/// tolerance of the f64 path (tolerance-tested, not bit-locked). The
/// iteration schedule may differ: the ‖ΔT̃‖ stopping test reads the f32
/// plan buffers, so once updates fall below f32 resolution the f32 lane
/// stops (reporting `converged` certified only at storage resolution)
/// while the f64 run may keep iterating.
pub fn spar_gw_with_workspace_f32(
    p: &GwProblem,
    cost: GroundCost,
    cfg: &SparGwConfig,
    set: &SampledSet,
    ws: &mut Workspace,
) -> SparGwResult {
    let ctx = SparseCostContext::new(p.cx, p.cy, &set.rows, &set.cols, cost);
    let a32: Vec<f32> = p.a.iter().map(|&x| x as f32).collect();
    let b32: Vec<f32> = p.b.iter().map(|&x| x as f32).collect();
    let eng = Engine {
        a: &a32,
        b: &b32,
        a64: p.a,
        b64: p.b,
        set,
        ctx: &ctx,
        outer_iters: cfg.outer_iters,
        tol: cfg.tol,
    };
    let mut strategy =
        Balanced { epsilon: cfg.epsilon, reg: cfg.reg, inner_iters: cfg.inner_iters };
    eng.solve(&mut strategy, ws.lane32())
}

/// Registry solver for Algorithm 2 (`"spar_gw"`): samples the index set
/// from the caller's RNG, then runs the SparCore engine on the caller's
/// workspace. Extends to the fused objective through the [`Fused`
/// strategy](super::core::Fused) (same engine Spar-FGW uses), matching the
/// coordinator's historical attribute handling.
pub struct SparGwSolver {
    /// Ground cost `L`.
    pub cost: GroundCost,
    /// Algorithm-2 parameters.
    pub cfg: SparGwConfig,
    /// Kernel precision: `F64` (default, bit-identical to the historical
    /// path) or `F32` (mixed precision — the sampling factors, coupling
    /// updates and inner Sinkhorn run at half width; the final ĜW, plan
    /// and report stay f64).
    pub precision: Precision,
}

impl SparGwSolver {
    pub(crate) fn from_opts(base: &SolverBase, o: &mut Opts) -> Result<Self> {
        Ok(SparGwSolver {
            cost: o.cost(base.cost)?,
            cfg: SparGwConfig {
                epsilon: o.f64("epsilon", base.epsilon)?,
                sample_size: o.usize("s", base.sample_size)?,
                outer_iters: o.usize("outer", base.outer_iters)?,
                inner_iters: o.usize("inner", base.inner_iters)?,
                reg: o.reg(base.reg)?,
                shrink: o.f64("shrink", base.shrink)?,
                tol: o.f64("tol", base.tol)?,
            },
            precision: o.precision(base.precision)?,
        })
    }

    /// Steps 2–3: the Eq. (5) sampler on the problem marginals, with the
    /// `√·` factors computed at the solver's precision (identical to the
    /// historical sampler at f64).
    fn sample(&self, a: &[f64], b: &[f64], rng: &mut Rng) -> SampledSet {
        let fa = SideFactors::with_precision(a, self.precision);
        let fb = SideFactors::with_precision(b, self.precision);
        self.sample_cached(&fa, &fb, rng)
    }

    /// Steps 2–3 from cached per-side factors — bit-identical draws to
    /// [`SparGwSolver::sample`] on the marginals the factors came from.
    fn sample_cached(&self, fa: &SideFactors, fb: &SideFactors, rng: &mut Rng) -> SampledSet {
        let sampler = GwSampler::from_factors(fa, fb, self.cfg.shrink);
        sampler.sample_iid(rng, self.budget(fa.len(), fb.len()))
    }

    fn budget(&self, m: usize, n: usize) -> usize {
        if self.cfg.sample_size == 0 { 16 * m.max(n) } else { self.cfg.sample_size }
    }
}

impl GwSolver for SparGwSolver {
    fn name(&self) -> &'static str {
        "spar_gw"
    }

    fn solve(&self, p: &GwProblem, rng: &mut Rng, ws: &mut Workspace) -> Result<SolveReport> {
        let t0 = Instant::now();
        let set = self.sample(p.a, p.b, rng);
        self.solve_with_set(p, &set, t0.elapsed().as_secs_f64(), ws)
    }

    fn supports_fused(&self) -> bool {
        true
    }

    fn solve_fused(
        &self,
        p: &FgwProblem,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<SolveReport> {
        let t0 = Instant::now();
        let set = self.sample(p.gw.a, p.gw.b, rng);
        self.solve_fused_with_set(p, &set, t0.elapsed().as_secs_f64(), ws)
    }

    fn solve_prepared(
        &self,
        p: &GwProblem,
        sx: &PreparedStructure,
        sy: &PreparedStructure,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<SolveReport> {
        let t0 = Instant::now();
        let set =
            self.sample_cached(sx.factors_for(self.precision), sy.factors_for(self.precision), rng);
        self.solve_with_set(p, &set, t0.elapsed().as_secs_f64(), ws)
    }

    fn solve_fused_prepared(
        &self,
        p: &FgwProblem,
        sx: &PreparedStructure,
        sy: &PreparedStructure,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<SolveReport> {
        let t0 = Instant::now();
        let set =
            self.sample_cached(sx.factors_for(self.precision), sy.factors_for(self.precision), rng);
        self.solve_fused_with_set(p, &set, t0.elapsed().as_secs_f64(), ws)
    }
}

impl SparGwSolver {
    /// Steps 4–8 on a ready index set (shared by the fresh and prepared
    /// entry points — the trajectories are identical once `set` is fixed).
    fn solve_with_set(
        &self,
        p: &GwProblem,
        set: &SampledSet,
        sample_seconds: f64,
        ws: &mut Workspace,
    ) -> Result<SolveReport> {
        let t1 = Instant::now();
        let r = match self.precision {
            Precision::F64 => spar_gw_with_workspace(p, self.cost, &self.cfg, set, ws),
            Precision::F32 => spar_gw_with_workspace_f32(p, self.cost, &self.cfg, set, ws),
        };
        Ok(SolveReport {
            solver: self.name(),
            value: r.value,
            plan: Plan::Sparse(r.plan),
            outer_iters: r.outer_iters,
            converged: r.converged,
            timings: PhaseTimings::basic(sample_seconds, t1.elapsed().as_secs_f64()),
        })
    }

    /// Algorithm 4 on a ready index set (fused objective).
    fn solve_fused_with_set(
        &self,
        p: &FgwProblem,
        set: &SampledSet,
        sample_seconds: f64,
        ws: &mut Workspace,
    ) -> Result<SolveReport> {
        let t1 = Instant::now();
        let r = match self.precision {
            Precision::F64 => {
                super::spar_fgw::spar_fgw_with_workspace(p, self.cost, &self.cfg, set, ws)
            }
            Precision::F32 => {
                super::spar_fgw::spar_fgw_with_workspace_f32(p, self.cost, &self.cfg, set, ws)
            }
        };
        Ok(SolveReport {
            solver: self.name(),
            value: r.value,
            plan: Plan::Sparse(r.plan),
            outer_iters: r.outer_iters,
            converged: r.converged,
            timings: PhaseTimings::basic(sample_seconds, t1.elapsed().as_secs_f64()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gw::alg1::{pga_gw, Alg1Config};
    use crate::linalg::Mat;
    use crate::rng::Xoshiro256;
    use crate::util::uniform;

    fn point_cloud_relation(n: usize, seed: u64, spread: f64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let pts: Vec<[f64; 2]> = (0..n)
            .map(|_| [rng.f64() * spread, rng.f64() * spread])
            .collect();
        Mat::from_fn(n, n, |i, j| {
            let dx = pts[i][0] - pts[j][0];
            let dy = pts[i][1] - pts[j][1];
            (dx * dx + dy * dy).sqrt()
        })
    }

    #[test]
    fn zero_for_identical_spaces() {
        let n = 20;
        let c = point_cloud_relation(n, 1, 1.0);
        let a = uniform(n);
        let p = GwProblem::new(&c, &c, &a, &a);
        let mut rng = Xoshiro256::new(7);
        let cfg = SparGwConfig { sample_size: 16 * n, ..Default::default() };
        let r = spar_gw(&p, GroundCost::L2, &cfg, &mut rng);
        // The sampled support misses some diagonal cells, so a small
        // positive bias remains even for identical spaces.
        assert!(r.value < 5e-2, "ĜW = {}", r.value);
    }

    #[test]
    fn plan_lives_on_sampled_support() {
        let n = 15;
        let c1 = point_cloud_relation(n, 2, 1.0);
        let c2 = point_cloud_relation(n, 3, 2.0);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let mut rng = Xoshiro256::new(8);
        let cfg = SparGwConfig { sample_size: 8 * n, ..Default::default() };
        let r = spar_gw(&p, GroundCost::L1, &cfg, &mut rng);
        assert_eq!(r.plan.nnz(), r.support);
        assert!(r.support <= 8 * n);
        // All stored values finite and non-negative.
        assert!(r.plan.vals().iter().all(|&v| v.is_finite() && v >= 0.0));
    }

    #[test]
    fn approximates_dense_pga_gw() {
        // The headline property (Fig. 2): with s = 16n the estimate lands
        // near the dense PGA-GW benchmark.
        let n = 30;
        let c1 = point_cloud_relation(n, 4, 1.0);
        let c2 = point_cloud_relation(n, 5, 1.5);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let dense_cfg = Alg1Config { epsilon: 0.01, outer_iters: 30, inner_iters: 60, tol: 1e-10 };
        let bench = pga_gw(&p, GroundCost::L2, &dense_cfg);

        let mut rng = Xoshiro256::new(9);
        let cfg = SparGwConfig {
            epsilon: 0.01,
            sample_size: 16 * n,
            outer_iters: 30,
            inner_iters: 60,
            ..Default::default()
        };
        // Average over several runs (sampled estimator).
        let mut vals = Vec::new();
        for _ in 0..5 {
            vals.push(spar_gw(&p, GroundCost::L2, &cfg, &mut rng).value);
        }
        let est = crate::util::mean(&vals);
        let rel = (est - bench.value).abs() / bench.value.max(1e-9);
        assert!(
            rel < 0.5,
            "Spar-GW {est} vs PGA-GW {} (rel err {rel})",
            bench.value
        );
    }

    #[test]
    fn error_decreases_with_sample_size() {
        // Fig. 4 behaviour: larger s ⇒ estimate closer to the dense value.
        let n = 25;
        let c1 = point_cloud_relation(n, 11, 1.0);
        let c2 = point_cloud_relation(n, 12, 1.8);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let dense_cfg = Alg1Config { epsilon: 0.01, outer_iters: 30, inner_iters: 60, tol: 1e-10 };
        let bench = pga_gw(&p, GroundCost::L2, &dense_cfg).value;

        let err_for = |s_mult: usize| {
            let cfg = SparGwConfig {
                epsilon: 0.01,
                sample_size: s_mult * n,
                outer_iters: 30,
                inner_iters: 60,
                ..Default::default()
            };
            let mut rng = Xoshiro256::new(100 + s_mult as u64);
            let mut errs = Vec::new();
            for _ in 0..6 {
                let v = spar_gw(&p, GroundCost::L2, &cfg, &mut rng).value;
                errs.push((v - bench).abs());
            }
            crate::util::mean(&errs)
        };
        let e_small = err_for(2);
        let e_large = err_for(24);
        assert!(
            e_large < e_small + 1e-9,
            "err(s=2n) = {e_small}, err(s=24n) = {e_large}"
        );
    }

    #[test]
    fn entropy_variant_runs() {
        let n = 12;
        let c1 = point_cloud_relation(n, 13, 1.0);
        let c2 = point_cloud_relation(n, 14, 1.0);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let mut rng = Xoshiro256::new(15);
        let cfg = SparGwConfig {
            reg: Regularizer::Entropy,
            sample_size: 10 * n,
            ..Default::default()
        };
        let r = spar_gw(&p, GroundCost::L2, &cfg, &mut rng);
        assert!(r.value.is_finite() && r.value >= -1e-9);
    }

    #[test]
    fn nonuniform_marginals_feasible_on_support() {
        let n = 18;
        let c1 = point_cloud_relation(n, 16, 1.0);
        let c2 = point_cloud_relation(n, 17, 1.0);
        let mut rng0 = Xoshiro256::new(18);
        let mut a: Vec<f64> = (0..n).map(|_| rng0.f64() + 0.1).collect();
        crate::util::normalize(&mut a);
        let b = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &b);
        let mut rng = Xoshiro256::new(19);
        let cfg = SparGwConfig { sample_size: 20 * n, shrink: 0.1, ..Default::default() };
        let r = spar_gw(&p, GroundCost::L2, &cfg, &mut rng);
        // Marginals approximately honored on rows with support.
        let rows = r.plan.row_sums();
        let mut total_err = 0.0;
        for i in 0..n {
            total_err += (rows[i] - a[i]).abs();
        }
        assert!(total_err < 0.35, "L1 marginal error {total_err}");
    }
}
