//! Implicit relation matrices for the million-point tier.
//!
//! Every historical solver takes `GwProblem`, which borrows *dense* n×n
//! relation matrices — at n = 10⁵ that is 80 GB per side, so the
//! hierarchical `qgw` tier needs relations it can evaluate **on demand**:
//! a point cloud whose relation entry `(i, j)` is the Euclidean distance
//! between points i and j, computed when asked, never materialized.
//!
//! [`Relation`] abstracts over the two representations:
//!
//! * `Dense(&Mat)` — the historical path (entry = stored value), so the
//!   registry's `GwSolver::solve` entry point funnels through the same
//!   code as the O(n)-memory path;
//! * `Points(&PointCloud)` — entry computed from coordinates with the
//!   *same* formula as [`crate::datasets::pairwise_euclidean`]
//!   (`sqdist(·,·).sqrt()`, accumulation in coordinate order), so a
//!   point-cloud solve is **bit-identical** to the equivalent dense solve
//!   on the materialized matrix.
//!
//! Only O(n·m) slices (anchor columns, gathered anchor blocks) are ever
//! allocated from a `Relation`; those fills run on the crate-wide worker
//! pool and are element-wise, hence bit-identical at any pool width.

use crate::linalg::{sqdist, Mat};
use crate::runtime::pool::pool;

/// A flat row-major point set: `n` points of dimension `dim` in one
/// contiguous allocation (O(n·dim) memory — the only per-space state the
/// million-point path keeps).
pub struct PointCloud {
    data: Vec<f64>,
    n: usize,
    dim: usize,
}

impl PointCloud {
    /// Flatten a `Vec<Vec<f64>>` point list (the dataset generators'
    /// output format). All points must share one dimension.
    pub fn from_points(pts: &[Vec<f64>]) -> Self {
        assert!(!pts.is_empty(), "PointCloud: empty point set");
        let dim = pts[0].len();
        assert!(dim > 0, "PointCloud: zero-dimensional points");
        let mut data = Vec::with_capacity(pts.len() * dim);
        for p in pts {
            assert_eq!(p.len(), dim, "PointCloud: ragged point set");
            data.extend_from_slice(p);
        }
        PointCloud { data, n: pts.len(), dim }
    }

    /// Number of points.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True for an empty cloud (never: construction asserts).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Coordinate dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The i-th point's coordinates.
    pub fn point(&self, i: usize) -> &[f64] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Euclidean distance between points i and j — the same
    /// `sqdist(·,·).sqrt()` evaluation `pairwise_euclidean` stores, so
    /// implicit and materialized relations agree bit-for-bit.
    pub fn dist(&self, i: usize, j: usize) -> f64 {
        sqdist(self.point(i), self.point(j)).sqrt()
    }
}

/// A relation matrix in whichever representation the caller holds: dense
/// (historical solvers, small n) or an implicit point cloud (the
/// million-point tier).
#[derive(Clone, Copy)]
pub enum Relation<'a> {
    /// Materialized n×n matrix; entries are reads.
    Dense(&'a Mat),
    /// Implicit Euclidean relation over a point cloud; entries are
    /// computed on demand.
    Points(&'a PointCloud),
}

impl Relation<'_> {
    /// Number of atoms n (the relation is n×n).
    pub fn len(&self) -> usize {
        match self {
            Relation::Dense(c) => c.rows(),
            Relation::Points(p) => p.len(),
        }
    }

    /// True for an empty relation.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Entry `(i, j)` of the relation matrix.
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        match self {
            Relation::Dense(c) => c[(i, j)],
            Relation::Points(p) => p.dist(i, j),
        }
    }

    /// Materialize the `rows × cols` sub-block (the qgw coarse problem
    /// gathers the m×m anchor block — O(m²), never O(n²)).
    pub fn gather(&self, rows: &[usize], cols: &[usize]) -> Mat {
        match self {
            Relation::Dense(c) => c.gather(rows, cols),
            Relation::Points(p) => {
                Mat::from_fn(rows.len(), cols.len(), |i, j| p.dist(rows[i], cols[j]))
            }
        }
    }

    /// Fill `out[i] = entry(i, j)` for a fixed column j (distance of every
    /// atom to one anchor). Element-wise on the worker pool: bit-identical
    /// at any width.
    pub fn column_into(&self, j: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.len(), "column_into: length mismatch");
        let this = *self;
        pool().for_each_chunk_mut(out, 4096, |chunk, range, _| {
            for (slot, i) in chunk.iter_mut().zip(range) {
                *slot = this.entry(i, j);
            }
        });
    }
}

// Safety-by-construction: both variants borrow immutable data, so sharing
// a `Relation` across pool workers is sound (Mat and PointCloud are Sync).

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::pairwise_euclidean;
    use crate::rng::Xoshiro256;

    fn random_points(n: usize, dim: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Xoshiro256::new(seed);
        (0..n).map(|_| (0..dim).map(|_| rng.f64()).collect()).collect()
    }

    #[test]
    fn points_entry_matches_materialized_matrix_bitwise() {
        let pts = random_points(17, 3, 1);
        let dense = pairwise_euclidean(&pts);
        let cloud = PointCloud::from_points(&pts);
        let rel = Relation::Points(&cloud);
        assert_eq!(rel.len(), 17);
        for i in 0..17 {
            for j in 0..17 {
                assert_eq!(
                    rel.entry(i, j).to_bits(),
                    dense[(i, j)].to_bits(),
                    "entry ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn gather_matches_dense_gather() {
        let pts = random_points(12, 2, 2);
        let dense = pairwise_euclidean(&pts);
        let cloud = PointCloud::from_points(&pts);
        let rows = [3, 0, 7];
        let cols = [1, 11, 5, 2];
        let gp = Relation::Points(&cloud).gather(&rows, &cols);
        let gd = Relation::Dense(&dense).gather(&rows, &cols);
        assert_eq!(gp.shape(), gd.shape());
        for i in 0..rows.len() {
            for j in 0..cols.len() {
                assert_eq!(gp[(i, j)].to_bits(), gd[(i, j)].to_bits());
            }
        }
    }

    #[test]
    fn column_fill_is_a_column_of_the_matrix() {
        let pts = random_points(33, 4, 3);
        let dense = pairwise_euclidean(&pts);
        let cloud = PointCloud::from_points(&pts);
        let mut col = vec![0.0; 33];
        Relation::Points(&cloud).column_into(9, &mut col);
        for i in 0..33 {
            assert_eq!(col[i].to_bits(), dense[(i, 9)].to_bits(), "row {i}");
        }
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_points_rejected() {
        PointCloud::from_points(&[vec![0.0, 1.0], vec![2.0]]);
    }
}
