//! S-GWL — Scalable Gromov-Wasserstein Learning (Xu, Luo & Carin 2019a),
//! adapted for arbitrary ground cost following Kerdoncuff et al. (2021),
//! as in §6.1(iv) of the paper.
//!
//! Simplified two-level multiscale reimplementation (documented in
//! DESIGN.md §4): both spaces are partitioned into k clusters (k-means on
//! relation-matrix rows), a coarse GW problem is solved between the
//! cluster-level relation matrices, cluster pairs with significant coarse
//! plan mass are matched, and a fine GW problem is solved inside each
//! matched pair; the block plans compose into a global sparse coupling.

use std::time::Instant;

use super::alg1::{pga_gw, Alg1Config};
use super::core::Workspace;
use super::cost::GroundCost;
use super::solver::{GwSolver, Opts, PhaseTimings, Plan, SolveReport, SolverBase};
use super::{DenseGwResult, GwProblem};
use crate::linalg::Mat;
use crate::ml::kmeans::kmeans;
use crate::rng::Rng;
use crate::util::error::Result;

/// Configuration for the multiscale solver.
#[derive(Clone, Copy, Debug)]
pub struct SgwlConfig {
    /// Number of clusters per space (0 → ⌈√n⌉).
    pub clusters: usize,
    /// Inner dense-GW configuration (used at both levels).
    pub inner: Alg1Config,
    /// Keep cluster pairs whose coarse mass exceeds this fraction of the
    /// uniform mass 1/k².
    pub mass_threshold: f64,
}

impl Default for SgwlConfig {
    fn default() -> Self {
        SgwlConfig {
            clusters: 0,
            inner: Alg1Config { epsilon: 0.01, outer_iters: 15, inner_iters: 40, tol: 1e-8 },
            mass_threshold: 0.5,
        }
    }
}

/// Partition indices into k groups by k-means on relation-matrix rows.
fn partition(c: &Mat, k: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let n = c.rows();
    let rows: Vec<Vec<f64>> = (0..n).map(|i| c.row(i).to_vec()).collect();
    let assign = kmeans(&rows, k, 25, rng);
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); k];
    for (i, &g) in assign.iter().enumerate() {
        groups[g].push(i);
    }
    groups.retain(|g| !g.is_empty());
    groups
}

/// Cluster-level relation matrix: block averages of `c` over the groups.
fn coarsen(c: &Mat, groups: &[Vec<usize>]) -> Mat {
    let k = groups.len();
    Mat::from_fn(k, k, |p, q| {
        let mut s = 0.0;
        for &i in &groups[p] {
            for &j in &groups[q] {
                s += c[(i, j)];
            }
        }
        s / (groups[p].len() * groups[q].len()) as f64
    })
}

/// Run the multiscale S-GWL solver.
pub fn sgwl(p: &GwProblem, cost: GroundCost, cfg: &SgwlConfig, rng: &mut Rng) -> DenseGwResult {
    let (m, n) = (p.m(), p.n());
    let k = if cfg.clusters == 0 {
        ((m.min(n) as f64).sqrt().ceil() as usize).clamp(2, 32)
    } else {
        cfg.clusters
    };

    // --- Level 1: partition and coarse solve ---
    let gx = partition(p.cx, k, rng);
    let gy = partition(p.cy, k, rng);
    let cx_c = coarsen(p.cx, &gx);
    let cy_c = coarsen(p.cy, &gy);
    let a_c: Vec<f64> = gx.iter().map(|g| g.iter().map(|&i| p.a[i]).sum()).collect();
    let b_c: Vec<f64> = gy.iter().map(|g| g.iter().map(|&j| p.b[j]).sum()).collect();
    let coarse = GwProblem::new(&cx_c, &cy_c, &a_c, &b_c);
    let coarse_res = pga_gw(&coarse, cost, &cfg.inner);

    // --- Level 2: fine solves inside matched cluster pairs ---
    let (kx, ky) = (gx.len(), gy.len());
    let thresh = cfg.mass_threshold / (kx * ky) as f64;
    let mut t = Mat::zeros(m, n);
    for pidx in 0..kx {
        for qidx in 0..ky {
            let w = coarse_res.plan[(pidx, qidx)];
            if w <= thresh {
                continue;
            }
            let xi = &gx[pidx];
            let yj = &gy[qidx];
            // Sub-relation matrices + renormalized marginals.
            let cx_s = p.cx.gather(xi, xi);
            let cy_s = p.cy.gather(yj, yj);
            let mut a_s: Vec<f64> = xi.iter().map(|&i| p.a[i]).collect();
            let mut b_s: Vec<f64> = yj.iter().map(|&j| p.b[j]).collect();
            crate::util::normalize(&mut a_s);
            crate::util::normalize(&mut b_s);
            let sub = GwProblem::new(&cx_s, &cy_s, &a_s, &b_s);
            let sub_res = pga_gw(&sub, cost, &cfg.inner);
            // Compose: block plan scaled by the coarse mass w.
            for (li, &i) in xi.iter().enumerate() {
                for (lj, &j) in yj.iter().enumerate() {
                    t[(i, j)] += w * sub_res.plan[(li, lj)];
                }
            }
        }
    }
    // Repair marginals (dropped low-mass blocks leave a deficit): add a
    // faint independent-coupling background, then Sinkhorn-project.
    let bg = Mat::outer(p.a, p.b);
    t.axpy(1e-6, &bg);
    let res = crate::ot::sinkhorn(p.a, p.b, &t, 500, 1e-10);
    let t = res.plan;

    // Evaluate the energy on the full matrices (block-sparse T keeps this
    // closer to O((n²/k)²) than n⁴ in practice, but we use the dispatching
    // tensor product for correctness).
    let value = super::tensor::tensor_product(p.cx, p.cy, &t, cost).frob_inner(&t);
    DenseGwResult { value, plan: t, outer_iters: coarse_res.outer_iters, converged: false }
}

/// Registry solver for the multiscale S-GWL (`"sgwl"`). The inner dense
/// solves inherit ε/R/H from the base config with the same caps the bench
/// suite has always applied (R ≤ 15, H ≤ 40 per level, tol 1e-8), so the
/// two-level scheme stays cheap even under generous outer settings.
pub struct SgwlSolver {
    /// Ground cost `L`.
    pub cost: GroundCost,
    /// Multiscale parameters.
    pub cfg: SgwlConfig,
}

impl SgwlSolver {
    pub(crate) fn from_opts(base: &SolverBase, o: &mut Opts) -> Result<Self> {
        o.precision_f64_only("sgwl", base.precision)?;
        Ok(SgwlSolver {
            cost: o.cost(base.cost)?,
            cfg: SgwlConfig {
                clusters: o.usize("clusters", 0)?,
                inner: Alg1Config {
                    epsilon: o.f64("epsilon", base.epsilon)?,
                    outer_iters: o.usize("outer", base.outer_iters.min(15))?,
                    inner_iters: o.usize("inner", base.inner_iters.min(40))?,
                    tol: o.f64("tol", 1e-8)?,
                },
                mass_threshold: o.f64("mass_threshold", 0.5)?,
            },
        })
    }
}

impl GwSolver for SgwlSolver {
    fn name(&self) -> &'static str {
        "sgwl"
    }

    fn solve(&self, p: &GwProblem, rng: &mut Rng, _ws: &mut Workspace) -> Result<SolveReport> {
        let t0 = Instant::now();
        let r = sgwl(p, self.cost, &self.cfg, rng);
        Ok(SolveReport {
            solver: self.name(),
            value: r.value,
            plan: Plan::Dense(r.plan),
            outer_iters: r.outer_iters,
            converged: r.converged,
            timings: PhaseTimings::basic(0.0, t0.elapsed().as_secs_f64()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::util::uniform;

    /// Two well-separated clusters of points.
    fn clustered_relation(n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let pts: Vec<[f64; 2]> = (0..n)
            .map(|i| {
                let off = if i < n / 2 { 0.0 } else { 10.0 };
                [rng.f64() + off, rng.f64()]
            })
            .collect();
        Mat::from_fn(n, n, |i, j| crate::linalg::sqdist(&pts[i], &pts[j]).sqrt())
    }

    #[test]
    fn feasible_plan() {
        let n = 16;
        let c1 = clustered_relation(n, 1);
        let c2 = clustered_relation(n, 2);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let mut rng = Xoshiro256::new(3);
        let r = sgwl(&p, GroundCost::L2, &SgwlConfig::default(), &mut rng);
        let rows = r.plan.row_sums();
        for i in 0..n {
            assert!((rows[i] - a[i]).abs() < 1e-4, "row {i}: {}", rows[i]);
        }
    }

    #[test]
    fn near_zero_for_identical_clustered_spaces() {
        let n = 16;
        let c = clustered_relation(n, 4);
        let a = uniform(n);
        let p = GwProblem::new(&c, &c, &a, &a);
        let mut rng = Xoshiro256::new(5);
        let cfg = SgwlConfig { clusters: 2, ..Default::default() };
        let r = sgwl(&p, GroundCost::L2, &cfg, &mut rng);
        // Multiscale composition is approximate (value scale here is ~10²
        // for the L2 cost on distances ~10); require it to be well below
        // the naive-plan energy.
        let a = uniform(n);
        let naive =
            super::super::tensor::gw_energy(&c, &c, &Mat::outer(&a, &a), GroundCost::L2);
        assert!(r.value < 0.5 * naive, "value {} vs naive {naive}", r.value);
    }

    #[test]
    fn l1_cost_supported() {
        let n = 12;
        let c1 = clustered_relation(n, 6);
        let c2 = clustered_relation(n, 7);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let mut rng = Xoshiro256::new(8);
        let r = sgwl(&p, GroundCost::L1, &SgwlConfig::default(), &mut rng);
        assert!(r.value.is_finite() && r.value >= -1e-9);
    }
}
