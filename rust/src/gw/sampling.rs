//! Importance sparsification — §3.1 of the paper.
//!
//! The sampling probability (Eq. 5) is the product form
//! `p_ij ∝ √(a_i b_j)`, derived from `T*_ij C*_ij ≤ c₀ √(a_i b_j)`.
//! Condition (H.4) requires `p_ij ≥ c₃/n²`, achieved by linear shrinkage
//! toward the uniform distribution (the standard subsampling trick the
//! paper cites).
//!
//! Two subsampling schemes are provided:
//! * [`GwSampler::sample_iid`] — `s` i.i.d. draws with replacement (Algorithm 2,
//!   step 3), de-duplicated into a unique index set with the
//!   `min(1, s·p_ij)` importance weights of the Poisson analysis
//!   (Appendix B) — the factor that makes `E[K̃] = K`.
//! * `sample_poisson` — element-wise independent selection with
//!   probability `min(1, s·p_ij)` (Braverman et al. 2021), used by the
//!   theory-validation benches.

use crate::kernel::Precision;
use crate::rng::{AliasTable, ProductAlias, Rng};
use crate::runtime::pool::{pool, PAR_GRAIN};

/// The sampled sparsity pattern `S` plus its importance weights.
#[derive(Clone, Debug)]
pub struct SampledSet {
    /// Row index of each selected element.
    pub rows: Vec<usize>,
    /// Column index of each selected element.
    pub cols: Vec<usize>,
    /// Inclusion weight `p*_ij = min(1, s·p_ij)` per selected element —
    /// divide kernel entries by this to keep the estimator unbiased.
    pub weights: Vec<f64>,
    /// Nominal sample budget s used to build the weights.
    pub budget: usize,
}

impl SampledSet {
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// One marginal's half of the Eq. (5) sampler: the `√a_i` factors as an
/// alias table. The product distribution factorizes per side, so these can
/// be computed **once per metric-measure space** and reused across every
/// pair that space participates in — the per-structure preprocessing the
/// coordinator's [`StructureCache`](crate::coordinator::cache) amortizes
/// over a K×K Gram computation. Assembling a [`GwSampler`] from two
/// `SideFactors` ([`GwSampler::from_factors`]) is bit-identical to
/// building it from the raw marginals ([`GwSampler::new`]).
#[derive(Clone, Debug)]
pub struct SideFactors {
    table: AliasTable,
    len: usize,
}

impl SideFactors {
    /// Compute `√marginal` and its alias table (O(n)).
    pub fn new(marginal: &[f64]) -> Self {
        SideFactors::with_precision(marginal, Precision::F64)
    }

    /// [`SideFactors::new`] with the `√·` factors computed at the given
    /// kernel precision: the marginal is rounded through the storage
    /// type, the square root taken at that width, and the result widened
    /// back for the (always-f64) alias machinery. At
    /// [`Precision::F64`] this is exactly [`SideFactors::new`] —
    /// bit-identical draws; at [`Precision::F32`] the sampling factors
    /// carry f32 resolution, matching the rest of the mixed-precision
    /// pipeline. The coordinator's `StructureCache` caches one instance
    /// per (structure, precision) via
    /// [`PreparedStructure::factors_for`](crate::gw::solver::PreparedStructure::factors_for).
    ///
    /// The `√·` map runs parallel over chunks of the marginal on the
    /// crate-wide pool (elementwise, so bits are thread-count-free); the
    /// alias-table build stays serial (it is a sequential partition of
    /// the probability mass).
    pub fn with_precision(marginal: &[f64], precision: Precision) -> Self {
        let mut u = vec![0.0f64; marginal.len()];
        pool().for_each_chunk_mut(&mut u, PAR_GRAIN, |chunk, range, _| {
            let src = &marginal[range];
            match precision {
                Precision::F64 => {
                    for (o, &x) in chunk.iter_mut().zip(src) {
                        *o = x.max(0.0).sqrt();
                    }
                }
                Precision::F32 => {
                    for (o, &x) in chunk.iter_mut().zip(src) {
                        *o = ((x.max(0.0) as f32).sqrt()) as f64;
                    }
                }
            }
        });
        SideFactors { table: AliasTable::new(&u), len: marginal.len() }
    }

    /// Number of atoms in the underlying marginal.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when built from an empty marginal (never: construction panics).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Importance sampling probabilities for balanced GW:
/// row factors `√a_i` and column factors `√b_j`, optionally shrunk toward
/// uniform: `p ← (1−θ)·p + θ/(mn)` (condition H.4, with c₃ = θ).
/// `shrink` in \[0,1\].
pub struct GwSampler {
    alias: ProductAlias,
    shrink: f64,
    m: usize,
    n: usize,
}

impl GwSampler {
    pub fn new(a: &[f64], b: &[f64], shrink: f64) -> Self {
        // The Eq. (5) part stays in product form (two-table alias, O(1)
        // draws); the uniform component of the mixture is drawn by a
        // Bernoulli(θ) branch, so sampling stays O(1) and the *exact*
        // mixture probability p_ij = (1−θ)·p⁽⁵⁾_ij + θ/(mn) ≥ θ/(mn)
        // satisfies (H.4) with c₃ = θ.
        GwSampler::from_factors(&SideFactors::new(a), &SideFactors::new(b), shrink)
    }

    /// Assemble the sampler from precomputed per-side factors, skipping
    /// the O(m)+O(n) `√·`/alias-table builds. Draws and probabilities are
    /// bit-identical to [`GwSampler::new`] on the marginals the factors
    /// were built from.
    pub fn from_factors(fa: &SideFactors, fb: &SideFactors, shrink: f64) -> Self {
        assert!((0.0..=1.0).contains(&shrink), "shrink must be in [0,1]");
        GwSampler {
            alias: ProductAlias::from_tables(fa.table.clone(), fb.table.clone()),
            shrink,
            m: fa.len,
            n: fb.len,
        }
    }

    /// Normalized inclusion probability of pair (i, j).
    pub fn prob_of(&self, i: usize, j: usize) -> f64 {
        (1.0 - self.shrink) * self.alias.prob_of(i, j)
            + self.shrink / (self.m * self.n) as f64
    }

    /// Algorithm 2, step 3: draw `s` i.i.d. pairs, de-duplicate, and attach
    /// the `min(1, s·p_ij)` importance weights.
    pub fn sample_iid(&self, rng: &mut Rng, s: usize) -> SampledSet {
        let draws: Vec<(usize, usize)> = (0..s)
            .map(|_| {
                if self.shrink > 0.0 && rng.f64() < self.shrink {
                    // Uniform component of the (H.4) mixture.
                    (rng.usize(self.m), rng.usize(self.n))
                } else {
                    self.alias.sample(rng)
                }
            })
            .collect();
        // De-duplicate via sort on the flattened key.
        let mut keys: Vec<(usize, usize)> = draws;
        keys.sort_unstable();
        keys.dedup();
        let mut rows = Vec::with_capacity(keys.len());
        let mut cols = Vec::with_capacity(keys.len());
        let mut weights = Vec::with_capacity(keys.len());
        for (i, j) in keys {
            rows.push(i);
            cols.push(j);
            weights.push((s as f64 * self.prob_of(i, j)).min(1.0));
        }
        SampledSet { rows, cols, weights, budget: s }
    }
}

/// Poisson subsampling (Appendix B): select each of the m·n elements
/// independently with probability `min(1, s·p_ij)`. Expected size ≤ s.
/// O(mn) — used for theory validation, not the production path.
pub fn sample_poisson(
    rng: &mut Rng,
    a: &[f64],
    b: &[f64],
    shrink: f64,
    s: usize,
) -> SampledSet {
    let sampler = GwSampler::new(a, b, shrink);
    let (m, n) = (a.len(), b.len());
    let mut rows = Vec::new();
    let mut cols = Vec::new();
    let mut weights = Vec::new();
    for i in 0..m {
        for j in 0..n {
            let p_star = (s as f64 * sampler.prob_of(i, j)).min(1.0);
            if rng.f64() < p_star {
                rows.push(i);
                cols.push(j);
                weights.push(p_star);
            }
        }
    }
    SampledSet { rows, cols, weights, budget: s }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::uniform;

    #[test]
    fn probabilities_normalized() {
        let a = vec![0.1, 0.2, 0.7];
        let b = vec![0.5, 0.5];
        let s = GwSampler::new(&a, &b, 0.0);
        let mut total = 0.0;
        for i in 0..3 {
            for j in 0..2 {
                total += s.prob_of(i, j);
            }
        }
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn matches_eq5_without_shrinkage() {
        // p_ij ∝ √(a_i b_j)
        let a = [0.25f64, 0.75];
        let b = [0.4f64, 0.6];
        let s = GwSampler::new(&a, &b, 0.0);
        let mut z = 0.0f64;
        for i in 0..2 {
            for j in 0..2 {
                z += (a[i] * b[j]).sqrt();
            }
        }
        for i in 0..2 {
            for j in 0..2 {
                let expect = (a[i] * b[j]).sqrt() / z;
                assert!(
                    (s.prob_of(i, j) - expect).abs() < 1e-12,
                    "p({i},{j}) = {} vs {expect}",
                    s.prob_of(i, j)
                );
            }
        }
    }

    #[test]
    fn shrinkage_lower_bounds_probability() {
        // With shrink θ, p_ij ≥ θ²/(mn) — condition (H.4).
        let mut a = vec![1e-9, 1.0 - 1e-9];
        let b = vec![0.5, 0.5];
        crate::util::normalize(&mut a);
        let theta = 0.3;
        let s = GwSampler::new(&a, &b, theta);
        let bound = theta * theta / 4.0;
        for i in 0..2 {
            for j in 0..2 {
                assert!(
                    s.prob_of(i, j) >= bound * (1.0 - 1e-9),
                    "p({i},{j}) = {} < {bound}",
                    s.prob_of(i, j)
                );
            }
        }
    }

    #[test]
    fn f64_precision_factors_are_bit_identical_to_new() {
        let a = vec![0.12, 0.38, 0.5];
        let plain = SideFactors::new(&a);
        let prec = SideFactors::with_precision(&a, Precision::F64);
        // Same factor tables ⇒ same probabilities and same draws.
        let s1 = GwSampler::from_factors(&plain, &plain, 0.0);
        let s2 = GwSampler::from_factors(&prec, &prec, 0.0);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(s1.prob_of(i, j).to_bits(), s2.prob_of(i, j).to_bits());
            }
        }
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let set1 = s1.sample_iid(&mut r1, 40);
        let set2 = s2.sample_iid(&mut r2, 40);
        assert_eq!(set1.rows, set2.rows);
        assert_eq!(set1.cols, set2.cols);
    }

    #[test]
    fn f32_precision_factors_stay_close_and_normalized() {
        let a = vec![0.01, 0.19, 0.3, 0.5];
        let f32f = SideFactors::with_precision(&a, Precision::F32);
        let s = GwSampler::from_factors(&f32f, &f32f, 0.0);
        let mut total = 0.0;
        for i in 0..4 {
            for j in 0..4 {
                total += s.prob_of(i, j);
            }
        }
        assert!((total - 1.0).abs() < 1e-9, "total {total}");
        // Quantized factors drift from f64 by at most f32 rounding.
        let f64f = SideFactors::new(&a);
        let s64 = GwSampler::from_factors(&f64f, &f64f, 0.0);
        for i in 0..4 {
            for j in 0..4 {
                let d = (s.prob_of(i, j) - s64.prob_of(i, j)).abs();
                assert!(d < 1e-6, "p({i},{j}) drift {d}");
            }
        }
    }

    #[test]
    fn iid_sample_dedup_and_weights() {
        let a = uniform(10);
        let b = uniform(10);
        let s = GwSampler::new(&a, &b, 0.0);
        let mut rng = Rng::new(21);
        let set = s.sample_iid(&mut rng, 160);
        assert!(!set.is_empty());
        assert!(set.len() <= 160);
        // Unique pairs.
        let mut seen: Vec<(usize, usize)> =
            set.rows.iter().cloned().zip(set.cols.iter().cloned()).collect();
        seen.sort_unstable();
        let before = seen.len();
        seen.dedup();
        assert_eq!(before, seen.len(), "duplicates remained");
        // Weights in (0, 1].
        for &w in &set.weights {
            assert!(w > 0.0 && w <= 1.0);
        }
    }

    #[test]
    fn poisson_expected_size() {
        let n = 30;
        let a = uniform(n);
        let b = uniform(n);
        let mut rng = Rng::new(31);
        let s = 5 * n;
        let mut sizes = Vec::new();
        for _ in 0..20 {
            sizes.push(sample_poisson(&mut rng, &a, &b, 0.0, s).len() as f64);
        }
        let mean = crate::util::mean(&sizes);
        // E|S| = Σ min(1, s·p) = s when s·p ≤ 1 everywhere (uniform case).
        assert!(
            (mean - s as f64).abs() < 0.15 * s as f64,
            "mean size {mean} vs budget {s}"
        );
    }

    #[test]
    fn unbiased_sum_estimate() {
        // Σ_ij X_ij estimated by Σ_{S} X_ij / p*_ij is unbiased under
        // Poisson sampling: check the Monte-Carlo average is close.
        let n = 12;
        let a = uniform(n);
        let b = uniform(n);
        let x = |i: usize, j: usize| ((i * n + j) as f64 * 0.37).sin().abs() + 0.1;
        let truth: f64 = (0..n)
            .flat_map(|i| (0..n).map(move |j| x(i, j)))
            .sum();
        let mut rng = Rng::new(41);
        let mut estimates = Vec::new();
        for _ in 0..200 {
            let set = sample_poisson(&mut rng, &a, &b, 0.0, 4 * n);
            let est: f64 = set
                .rows
                .iter()
                .zip(&set.cols)
                .zip(&set.weights)
                .map(|((&i, &j), &w)| x(i, j) / w)
                .sum();
            estimates.push(est);
        }
        let mean = crate::util::mean(&estimates);
        assert!(
            (mean - truth).abs() < 0.05 * truth,
            "estimator mean {mean} vs truth {truth}"
        );
    }
}
