//! **SparCore** — the shared engine behind the Spar-* solver family.
//!
//! Algorithms 2 (Spar-GW), 3 (Spar-UGW) and 4 (Spar-FGW) share one
//! iteration skeleton: sample `S` → O(s²) sparse cost → importance-
//! corrected kernel → sparse Sinkhorn → plan update. This module owns that
//! skeleton once; the per-variant physics (initial plan, kernel formula,
//! inner scaling solver, acceptance rule, objective) is injected through
//! the small [`Marginals`] strategy trait, so `spar_gw`, `spar_fgw` and
//! `spar_ugw` are thin adapters over [`Engine::solve`].
//!
//! Since the kernel-layer refactor the whole engine is generic over the
//! kernel [`Scalar`]: [`Workspace<S>`], [`Engine<S>`] and the strategies
//! run the coupling updates, kernel exponentials and inner Sinkhorn at
//! storage width `S`, while marginal sums, the outer stopping criterion,
//! the final objective and the returned plan stay f64 (the accumulator
//! rule — see `kernel::scalar`). At `S = f64` every operation matches
//! the historical implementation bit-for-bit; `precision=f32` is reached
//! through the f64 workspace's lazily allocated
//! [`f32 lane`](Workspace::lane32), so the `GwSolver` interface and the
//! coordinator's per-worker workspace reuse are unchanged.
//!
//! The engine runs on a per-solve [`Workspace`] of preallocated buffers
//! plus a CSR view of the sampled pattern built once per solve: the
//! inner H×R loop performs **zero heap allocations** (verified by the
//! counting allocator in `benches/perf_micro.rs` — the persistent pool's
//! dispatch is allocation-free too), and the coordinator reuses one
//! `Workspace` per worker thread across pairs. The O(s²) sparse-cost
//! kernel, the CSR Sinkhorn sweeps and the scaling updates all run on
//! the crate-wide worker pool ([`crate::runtime::pool`]) when the work
//! clears the per-kernel grain; chunking never changes results, because
//! every chunk owns disjoint outputs with the serial per-output
//! operation order — bit-identical at any `SPARGW_THREADS`.
//!
//! Numerical contract: every strategy reproduces the pre-refactor solver
//! loops operation-for-operation, so results are *bit-identical* to the
//! historical implementations (locked in by `tests/integration_solvers.rs`).

use super::sampling::SampledSet;
use super::spar_gw::SparGwResult;
use super::tensor::SparseCostContext;
use super::ugw::{kl_otimes, unbalanced_cost_shift};
use super::Regularizer;
use crate::kernel::{Precision, Scalar};
use crate::ot::{sparse_sinkhorn_fixed, sparse_unbalanced_sinkhorn_fixed};
use crate::sparse::{Coo, Csr};

/// Resize to `len` zeros, keeping capacity (the workspace-reuse primitive).
fn fit<S: Scalar>(buf: &mut Vec<S>, len: usize) {
    buf.clear();
    buf.resize(len, S::ZERO);
}

/// Preallocated per-solve buffers for the SparCore engine.
///
/// Create once ([`Workspace::new`]) and pass to any number of solves —
/// including solves of different shapes and different Spar-* variants; the
/// engine re-fits the buffers (retaining capacity) at the start of each
/// solve. One workspace must not be shared across threads concurrently;
/// the coordinator keeps one per worker. The default `Workspace` (f64)
/// lazily owns an f32 sibling ([`Workspace::lane32`]) so mixed-precision
/// solves reuse the same per-worker object.
#[derive(Default)]
pub struct Workspace<S: Scalar = f64> {
    /// CSR view of the sampled pattern, rebuilt per solve.
    csr: Csr,
    /// Importance corrections 1/p*_l, entry order.
    inv_w: Vec<S>,
    /// Current plan values T̃ on the pattern.
    t: Vec<S>,
    /// Candidate next plan (swapped into `t` on acceptance).
    t_next: Vec<S>,
    /// Sparse cost values C̃(T̃) (also the energy scratch).
    c_vals: Vec<S>,
    /// Stabilized (rank-one-reduced) cost values.
    c_red: Vec<S>,
    /// Kernel values K̃.
    k_vals: Vec<S>,
    /// Per-row pattern minima (stabilization).
    row_min: Vec<S>,
    /// Per-column pattern minima (stabilization).
    col_min: Vec<S>,
    /// Sinkhorn row scalings.
    u: Vec<S>,
    /// Sinkhorn column scalings.
    v: Vec<S>,
    /// Scratch K·v.
    kv: Vec<S>,
    /// Scratch Kᵀ·u.
    ktu: Vec<S>,
    /// Plan row marginals (unbalanced shift / objective) — marginal sums
    /// stay f64 at every storage width.
    row_sums: Vec<f64>,
    /// Plan column marginals (f64; see `row_sums`).
    col_sums: Vec<f64>,
    /// f64 staging buffer for the returned plan values (reused across
    /// solves so the widening copy allocates nothing when warm).
    t_out: Vec<f64>,
    /// Lazily allocated f32 sibling for mixed-precision solves (always
    /// `None` on non-f64 instantiations).
    lane32: Option<Box<Workspace<f32>>>,
}

impl<S: Scalar> Workspace<S> {
    pub fn new() -> Self {
        Workspace::default()
    }

    /// Fit every buffer to an (m, n, s) problem and rebuild the CSR
    /// pattern. All allocation for the solve happens here, before the
    /// outer loop.
    fn prepare(&mut self, m: usize, n: usize, set: &SampledSet) {
        let s = set.len();
        fit(&mut self.t, s);
        fit(&mut self.t_next, s);
        fit(&mut self.c_vals, s);
        fit(&mut self.c_red, s);
        fit(&mut self.k_vals, s);
        fit(&mut self.row_min, m);
        fit(&mut self.col_min, n);
        fit(&mut self.u, m);
        fit(&mut self.v, n);
        fit(&mut self.kv, m);
        fit(&mut self.ktu, n);
        fit(&mut self.row_sums, m);
        fit(&mut self.col_sums, n);
        fit(&mut self.t_out, s);
        self.inv_w.clear();
        self.inv_w.extend(set.weights.iter().map(|&w| S::from_f64(1.0 / w)));
        self.csr.rebuild(m, n, &set.rows, &set.cols);
    }
}

impl Workspace<f64> {
    /// The f32 sibling workspace, created on first use and reused across
    /// solves — mixed-precision solves ride the coordinator's per-worker
    /// f64 workspace without changing the `GwSolver` signature.
    pub fn lane32(&mut self) -> &mut Workspace<f32> {
        self.lane32.get_or_insert_with(Default::default)
    }
}

/// The shared solve context: problem marginals (at storage width and, for
/// the f64-only physics, at full width), the sampled set, the
/// pre-gathered cost block, and the outer-loop controls.
pub struct Engine<'a, S: Scalar = f64> {
    /// Source marginal at storage width (length m).
    pub a: &'a [S],
    /// Target marginal at storage width (length n).
    pub b: &'a [S],
    /// Source marginal at full f64 width (the unbalanced mass terms and
    /// objectives always read these; identical storage at `S = f64`).
    pub a64: &'a [f64],
    /// Target marginal at full f64 width.
    pub b64: &'a [f64],
    /// The sampled pattern `S` with importance weights.
    pub set: &'a SampledSet,
    /// Pre-gathered s×s ground-cost block.
    pub ctx: &'a SparseCostContext,
    /// Outer iteration cap R.
    pub outer_iters: usize,
    /// Outer stopping tolerance on ‖ΔT̃‖_F (0 disables).
    pub tol: f64,
}

/// The per-variant physics of a Spar-* solver: balanced (Algorithm 2),
/// fused (Algorithm 4) or unbalanced (Algorithm 3) marginal handling.
///
/// Hook order per outer iteration: `begin_iter` → `build_kernel` →
/// `inner` → `accept`; returning `false` from `begin_iter`/`accept`
/// stops the loop keeping the last accepted plan (the degenerate-kernel
/// guards of the original solvers).
pub trait Marginals<S: Scalar> {
    /// Initial plan value at pattern cell (i, j).
    fn init(&self, a_i: S, b_j: S) -> S;

    /// Start-of-iteration state update (e.g. the unbalanced mass terms).
    fn begin_iter(&mut self, eng: &Engine<S>, ws: &mut Workspace<S>) -> bool {
        let _ = (eng, ws);
        true
    }

    /// Fill `ws.k_vals` (the importance-corrected kernel) from the current
    /// plan `ws.t`; responsible for running the sparse cost product.
    fn build_kernel(&mut self, eng: &Engine<S>, ws: &mut Workspace<S>);

    /// Run the inner scaling solver: `ws.k_vals` → candidate plan
    /// `ws.t_next`.
    fn inner(&mut self, eng: &Engine<S>, ws: &mut Workspace<S>);

    /// Validate (and possibly rescale) `ws.t_next`; `false` discards it
    /// and stops the outer loop.
    fn accept(&mut self, eng: &Engine<S>, ws: &mut Workspace<S>) -> bool {
        let _ = (eng, ws);
        true
    }

    /// Final objective at the plan `ws.t` (always f64).
    fn value(&self, eng: &Engine<S>, ws: &mut Workspace<S>) -> f64;
}

impl<S: Scalar> Engine<'_, S> {
    /// Run the shared outer loop with the given marginal strategy on a
    /// (reusable) workspace. The returned plan and value are f64 at every
    /// storage width.
    pub fn solve(&self, strategy: &mut dyn Marginals<S>, ws: &mut Workspace<S>) -> SparGwResult {
        let (m, n) = (self.a.len(), self.b.len());
        let s = self.set.len();
        assert!(s > 0, "empty sampled set");
        assert_eq!(self.ctx.s(), s, "SparseCostContext/sampled-set size mismatch");
        assert_eq!(self.a64.len(), m, "a64/a length mismatch");
        assert_eq!(self.b64.len(), n, "b64/b length mismatch");
        ws.prepare(m, n, self.set);

        for l in 0..s {
            ws.t[l] = strategy.init(self.a[self.set.rows[l]], self.b[self.set.cols[l]]);
        }

        let mut outer = 0;
        let mut converged = false;
        for _ in 0..self.outer_iters {
            if !strategy.begin_iter(self, ws) {
                break;
            }
            strategy.build_kernel(self, ws);
            strategy.inner(self, ws);
            if !strategy.accept(self, ws) {
                break;
            }
            outer += 1;
            if self.tol > 0.0 {
                let mut diff = 0.0;
                for (x, y) in ws.t_next.iter().zip(&ws.t) {
                    let d = (*x - *y).to_f64();
                    diff += d * d;
                }
                std::mem::swap(&mut ws.t, &mut ws.t_next);
                if diff.sqrt() < self.tol {
                    converged = true;
                    break;
                }
            } else {
                std::mem::swap(&mut ws.t, &mut ws.t_next);
            }
        }

        let value = strategy.value(self, ws);
        for (o, v) in ws.t_out.iter_mut().zip(&ws.t) {
            *o = v.to_f64();
        }
        let plan = Coo::from_triplets(m, n, &self.set.rows, &self.set.cols, &ws.t_out);
        SparGwResult { value, plan, outer_iters: outer, converged, support: s }
    }
}

/// Rank-one stabilization shared by the balanced and fused kernels:
/// balanced Sinkhorn is invariant to cost shifts `C_ij ← C_ij − r_i − c_j`,
/// so reduce `ws.c_vals` by per-row then per-column minima over the stored
/// pattern into `ws.c_red`, keeping `exp()` in range.
fn stabilize<S: Scalar>(eng: &Engine<S>, ws: &mut Workspace<S>) {
    let s = ws.c_vals.len();
    let rows = &eng.set.rows;
    let cols = &eng.set.cols;
    for v in ws.row_min.iter_mut() {
        *v = S::INFINITY;
    }
    for l in 0..s {
        let i = rows[l];
        if ws.c_vals[l] < ws.row_min[i] {
            ws.row_min[i] = ws.c_vals[l];
        }
    }
    for v in ws.col_min.iter_mut() {
        *v = S::INFINITY;
    }
    for l in 0..s {
        let v = ws.c_vals[l] - ws.row_min[rows[l]];
        let j = cols[l];
        if v < ws.col_min[j] {
            ws.col_min[j] = v;
        }
    }
    for l in 0..s {
        ws.c_red[l] = ws.c_vals[l] - ws.row_min[rows[l]] - ws.col_min[cols[l]];
    }
}

/// The balanced inner solver shared by the [`Balanced`] and [`Fused`]
/// strategies: H fixed sparse-Sinkhorn sweeps from `ws.k_vals` into
/// `ws.t_next`, entirely in workspace buffers.
fn balanced_inner<S: Scalar>(eng: &Engine<S>, ws: &mut Workspace<S>, inner_iters: usize) {
    sparse_sinkhorn_fixed(
        eng.a,
        eng.b,
        &ws.csr,
        &ws.k_vals,
        inner_iters,
        &mut ws.u,
        &mut ws.v,
        &mut ws.kv,
        &mut ws.ktu,
        &mut ws.t_next,
    );
}

/// Balanced marginals — Algorithm 2 (Spar-GW).
pub struct Balanced {
    /// Regularization weight ε.
    pub epsilon: f64,
    /// Proximal or entropic kernel.
    pub reg: Regularizer,
    /// Inner Sinkhorn iterations H.
    pub inner_iters: usize,
}

impl<S: Scalar> Marginals<S> for Balanced {
    fn init(&self, a_i: S, b_j: S) -> S {
        a_i * b_j
    }

    fn build_kernel(&mut self, eng: &Engine<S>, ws: &mut Workspace<S>) {
        eng.ctx.cost_values_into_threaded(&ws.t, &mut ws.c_vals);
        stabilize(eng, ws);
        let s = ws.t.len();
        let eps = S::from_f64(self.epsilon);
        // Paper: "replace its 0's at S with ∞'s" — a zero cost entry means
        // no sampled mass informed it; exp(−∞/ε) = 0 removes it from the
        // kernel for this round rather than giving it the maximal weight.
        match self.reg {
            Regularizer::Proximal => {
                for l in 0..s {
                    ws.k_vals[l] = if ws.c_vals[l] == S::ZERO && ws.t[l] == S::ZERO {
                        S::ZERO
                    } else {
                        (-ws.c_red[l] / eps).exp() * ws.t[l] * ws.inv_w[l]
                    };
                }
            }
            Regularizer::Entropy => {
                for l in 0..s {
                    ws.k_vals[l] = (-ws.c_red[l] / eps).exp() * ws.inv_w[l];
                }
            }
        }
    }

    fn inner(&mut self, eng: &Engine<S>, ws: &mut Workspace<S>) {
        balanced_inner(eng, ws, self.inner_iters);
    }

    fn accept(&mut self, _eng: &Engine<S>, ws: &mut Workspace<S>) -> bool {
        // Degenerate kernel (e.g. a severely under-informative sample
        // set): keep the last good plan instead of propagating NaNs.
        if !ws.t_next.iter().all(|v| v.is_finite()) {
            return false;
        }
        // f32 lane only: exp(-c_red/ε) underflows to 0 at c_red/ε ≈ 88
        // (vs ≈708 for f64), which zeroes the whole kernel and hence the
        // plan — finite, so the guard above misses it. Reject the empty
        // plan and keep the last good one. Not applied at f64 so the
        // historical trajectory stays bit-identical.
        if S::PRECISION == Precision::F32 {
            let mass: f64 = ws.t_next.iter().map(|v| v.to_f64()).sum();
            if mass <= 0.0 {
                return false;
            }
        }
        true
    }

    fn value(&self, eng: &Engine<S>, ws: &mut Workspace<S>) -> f64 {
        eng.ctx.energy_with(&ws.t, &mut ws.c_vals)
    }
}

/// Fused marginals — Algorithm 4 (Spar-FGW): the balanced kernel over the
/// mixed cost `α·C̃(T̃) + (1−α)·M̃`, objective `α·ĜW + (1−α)·⟨M̃, T̃⟩`.
pub struct Fused<'m, S: Scalar = f64> {
    /// Regularization weight ε.
    pub epsilon: f64,
    /// Proximal or entropic kernel.
    pub reg: Regularizer,
    /// Inner Sinkhorn iterations H.
    pub inner_iters: usize,
    /// Structure/feature trade-off α.
    pub alpha: f64,
    /// Feature distances M̃ at the sampled positions (entry order, at
    /// storage width).
    pub feat_vals: &'m [S],
}

impl<S: Scalar> Marginals<S> for Fused<'_, S> {
    fn init(&self, a_i: S, b_j: S) -> S {
        a_i * b_j
    }

    fn build_kernel(&mut self, eng: &Engine<S>, ws: &mut Workspace<S>) {
        eng.ctx.cost_values_into_threaded(&ws.t, &mut ws.c_vals);
        let s = ws.t.len();
        let alpha = S::from_f64(self.alpha);
        let one_minus = S::from_f64(1.0 - self.alpha);
        for l in 0..s {
            ws.c_vals[l] = alpha * ws.c_vals[l] + one_minus * self.feat_vals[l];
        }
        stabilize(eng, ws);
        let eps = S::from_f64(self.epsilon);
        for l in 0..s {
            let e = (-ws.c_red[l] / eps).exp();
            ws.k_vals[l] = match self.reg {
                Regularizer::Proximal => e * ws.t[l] * ws.inv_w[l],
                Regularizer::Entropy => e * ws.inv_w[l],
            };
        }
    }

    fn inner(&mut self, eng: &Engine<S>, ws: &mut Workspace<S>) {
        balanced_inner(eng, ws, self.inner_iters);
    }

    fn accept(&mut self, _eng: &Engine<S>, ws: &mut Workspace<S>) -> bool {
        // f32 lane only (see [`Balanced::accept`]): reject the all-zero /
        // non-finite plan an underflowed f32 kernel produces. The f64
        // path keeps its historical unconditional accept bit-for-bit.
        if S::PRECISION == Precision::F64 {
            return true;
        }
        ws.t_next.iter().all(|v| v.is_finite())
            && ws.t_next.iter().map(|v| v.to_f64()).sum::<f64>() > 0.0
    }

    fn value(&self, eng: &Engine<S>, ws: &mut Workspace<S>) -> f64 {
        let gw_term = eng.ctx.energy_with(&ws.t, &mut ws.c_vals);
        let w_term: f64 = self
            .feat_vals
            .iter()
            .zip(&ws.t)
            .map(|(m, t)| m.to_f64() * t.to_f64())
            .sum();
        self.alpha * gw_term + (1.0 - self.alpha) * w_term
    }
}

/// Unbalanced marginals — Algorithm 3 (Spar-UGW): mass-dependent ε̄/λ̄, the
/// scalar `E(T̃)` cost shift, the λ̄/(λ̄+ε̄)-exponent inner solver, the mass
/// rescaling step, and the KL⊗-penalized objective. The mass terms, cost
/// shift and objective always run in f64 (they are marginal sums).
pub struct Unbalanced {
    /// Marginal relaxation weight λ.
    pub lambda: f64,
    /// Regularization weight ε.
    pub epsilon: f64,
    /// Inner unbalanced-Sinkhorn iterations H.
    pub inner_iters: usize,
    /// Initial-plan normalizer 1/√(m(a)·m(b)).
    norm0: f64,
    /// Plan mass at the top of the current iteration.
    mass: f64,
    /// ε̄ = ε·mass for the current iteration.
    eps_bar: f64,
    /// λ̄ = λ·mass for the current iteration.
    lam_bar: f64,
}

impl Unbalanced {
    pub fn new(lambda: f64, epsilon: f64, inner_iters: usize, a: &[f64], b: &[f64]) -> Self {
        let ma: f64 = a.iter().sum();
        let mb: f64 = b.iter().sum();
        Unbalanced {
            lambda,
            epsilon,
            inner_iters,
            norm0: 1.0 / (ma * mb).sqrt(),
            mass: 0.0,
            eps_bar: 0.0,
            lam_bar: 0.0,
        }
    }
}

impl<S: Scalar> Marginals<S> for Unbalanced {
    fn init(&self, a_i: S, b_j: S) -> S {
        a_i * b_j * S::from_f64(self.norm0)
    }

    fn begin_iter(&mut self, _eng: &Engine<S>, ws: &mut Workspace<S>) -> bool {
        let mass: f64 = ws.t.iter().map(|v| v.to_f64()).sum();
        if mass <= 0.0 || !mass.is_finite() {
            return false;
        }
        self.mass = mass;
        self.eps_bar = self.epsilon * mass;
        self.lam_bar = self.lambda * mass;
        true
    }

    fn build_kernel(&mut self, eng: &Engine<S>, ws: &mut Workspace<S>) {
        // Step 8a: sparse unbalanced cost = sparse product + E(T̃) shift.
        eng.ctx.cost_values_into_threaded(&ws.t, &mut ws.c_vals);
        ws.csr.row_sums_wide(&ws.t, &mut ws.row_sums);
        ws.csr.col_sums_wide(&ws.t, &mut ws.col_sums);
        let shift =
            unbalanced_cost_shift(&ws.row_sums, &ws.col_sums, eng.a64, eng.b64, self.lambda);
        // Step 8b: K̃ = exp(−C̃_un/ε̄) ⊙ T̃ ⊘ (sP).
        let s = ws.t.len();
        let shift_s = S::from_f64(shift);
        let eps_bar = S::from_f64(self.eps_bar);
        for l in 0..s {
            ws.k_vals[l] = (-(ws.c_vals[l] + shift_s) / eps_bar).exp() * ws.t[l] * ws.inv_w[l];
        }
    }

    fn inner(&mut self, eng: &Engine<S>, ws: &mut Workspace<S>) {
        sparse_unbalanced_sinkhorn_fixed(
            eng.a,
            eng.b,
            &ws.csr,
            &ws.k_vals,
            self.lam_bar,
            self.eps_bar,
            self.inner_iters,
            &mut ws.u,
            &mut ws.v,
            &mut ws.kv,
            &mut ws.ktu,
            &mut ws.t_next,
        );
    }

    fn accept(&mut self, _eng: &Engine<S>, ws: &mut Workspace<S>) -> bool {
        // Step 10: mass rescaling; kernel over/underflow (extreme λ/ε)
        // keeps the last good plan.
        let next_mass: f64 = ws.t_next.iter().map(|v| v.to_f64()).sum();
        if !next_mass.is_finite() || next_mass <= 0.0 {
            return false;
        }
        let scale = S::from_f64((self.mass / next_mass).sqrt());
        for x in ws.t_next.iter_mut() {
            *x *= scale;
        }
        true
    }

    fn value(&self, eng: &Engine<S>, ws: &mut Workspace<S>) -> f64 {
        // Step 11: ÛGW = quadratic term (on support) + λ KL⊗ penalties.
        let quad = eng.ctx.energy_with(&ws.t, &mut ws.c_vals);
        ws.csr.row_sums_wide(&ws.t, &mut ws.row_sums);
        ws.csr.col_sums_wide(&ws.t, &mut ws.col_sums);
        quad + self.lambda * kl_otimes(&ws.row_sums, eng.a64)
            + self.lambda * kl_otimes(&ws.col_sums, eng.b64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gw::cost::GroundCost;
    use crate::gw::sampling::GwSampler;
    use crate::gw::spar_gw::{
        spar_gw_with_set, spar_gw_with_workspace, spar_gw_with_workspace_f32, SparGwConfig,
    };
    use crate::gw::GwProblem;
    use crate::linalg::Mat;
    use crate::rng::Xoshiro256;
    use crate::util::uniform;

    fn relation(n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let pts: Vec<[f64; 2]> = (0..n).map(|_| [rng.f64(), rng.f64()]).collect();
        Mat::from_fn(n, n, |i, j| crate::linalg::sqdist(&pts[i], &pts[j]).sqrt())
    }

    #[test]
    fn workspace_reuse_across_shapes_is_deterministic() {
        // One workspace serving problems of different sizes must give the
        // same bits as fresh workspaces.
        let mut ws = Workspace::new();
        for (n, seed) in [(14usize, 1u64), (22, 2), (9, 3)] {
            let c1 = relation(n, seed);
            let c2 = relation(n, seed + 10);
            let a = uniform(n);
            let p = GwProblem::new(&c1, &c2, &a, &a);
            let sampler = GwSampler::new(&a, &a, 0.0);
            let mut rng = Xoshiro256::new(seed + 20);
            let set = sampler.sample_iid(&mut rng, 8 * n);
            let cfg = SparGwConfig { sample_size: 8 * n, ..Default::default() };
            let fresh = spar_gw_with_set(&p, GroundCost::L2, &cfg, &set);
            let reused = spar_gw_with_workspace(&p, GroundCost::L2, &cfg, &set, &mut ws);
            assert_eq!(fresh.value.to_bits(), reused.value.to_bits());
            assert_eq!(fresh.outer_iters, reused.outer_iters);
            for (x, y) in fresh.plan.vals().iter().zip(reused.plan.vals()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn threaded_solve_bit_identical_to_serial() {
        use crate::runtime::pool::with_thread_limit;
        let n = 26;
        let c1 = relation(n, 5);
        let c2 = relation(n, 6);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let sampler = GwSampler::new(&a, &a, 0.0);
        let mut rng = Xoshiro256::new(7);
        let set = sampler.sample_iid(&mut rng, 16 * n);
        let cfg = SparGwConfig { sample_size: 16 * n, ..Default::default() };
        let mut ws1 = Workspace::new();
        let mut ws4 = Workspace::new();
        let serial = with_thread_limit(1, || {
            spar_gw_with_workspace(&p, GroundCost::L1, &cfg, &set, &mut ws1)
        });
        let threaded = with_thread_limit(4, || {
            spar_gw_with_workspace(&p, GroundCost::L1, &cfg, &set, &mut ws4)
        });
        assert_eq!(serial.value.to_bits(), threaded.value.to_bits());
        for (x, y) in serial.plan.vals().iter().zip(threaded.plan.vals()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn f32_engine_tracks_f64_on_shared_set() {
        // Same sampled set, same iteration schedule: the f32 lane's
        // estimate must land within mixed-precision tolerance of f64 —
        // far tighter than the estimator's own sampling noise.
        let n = 24;
        let c1 = relation(n, 8);
        let c2 = relation(n, 9);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let sampler = GwSampler::new(&a, &a, 0.0);
        let mut rng = Xoshiro256::new(10);
        let set = sampler.sample_iid(&mut rng, 12 * n);
        let cfg = SparGwConfig { sample_size: 12 * n, ..Default::default() };
        let mut ws = Workspace::new();
        let r64 = spar_gw_with_workspace(&p, GroundCost::L2, &cfg, &set, &mut ws);
        let r32 = spar_gw_with_workspace_f32(&p, GroundCost::L2, &cfg, &set, &mut ws);
        assert!(r32.value.is_finite());
        let denom = r64.value.abs().max(1e-3);
        assert!(
            (r32.value - r64.value).abs() / denom < 0.05,
            "f32 {} vs f64 {}",
            r32.value,
            r64.value
        );
        // The f32 lane is reused (allocated once) across solves.
        let r32b = spar_gw_with_workspace_f32(&p, GroundCost::L2, &cfg, &set, &mut ws);
        assert_eq!(r32.value.to_bits(), r32b.value.to_bits());
    }
}
