//! Fused Gromov-Wasserstein distance (Titouan et al. 2019a; Vayer et al.
//! 2020) — Appendix A of the paper.
//!
//! `FGW = min_T α⟨L(Cx,Cy) ⊗ T, T⟩ + (1−α)⟨M, T⟩`
//!
//! where `M` is the feature distance matrix. Algorithm 1 applies verbatim
//! with the fused cost `C_fu(T) = α·L(Cx,Cy)⊗T + (1−α)·M`.

use super::alg1::Alg1Config;
use super::cost::GroundCost;
use super::tensor::tensor_product;
use super::{DenseGwResult, GwProblem, Regularizer};
use crate::linalg::Mat;
use crate::ot::{emd, sinkhorn};

/// A fused GW problem: structure (relation matrices) + features (M).
#[derive(Clone, Copy)]
pub struct FgwProblem<'a> {
    /// The structural part.
    pub gw: GwProblem<'a>,
    /// Feature distance matrix, m × n.
    pub feat: &'a Mat,
    /// Trade-off α in \[0,1\]: 1 → pure GW, 0 → pure Wasserstein.
    pub alpha: f64,
}

impl<'a> FgwProblem<'a> {
    pub fn new(gw: GwProblem<'a>, feat: &'a Mat, alpha: f64) -> Self {
        assert_eq!(feat.shape(), (gw.m(), gw.n()), "feature matrix shape");
        assert!((0.0..=1.0).contains(&alpha), "alpha in [0,1]");
        FgwProblem { gw, feat, alpha }
    }

    /// Fused cost `C_fu(T)`.
    pub fn fused_cost(&self, t: &Mat, cost: GroundCost) -> Mat {
        let mut c = tensor_product(self.gw.cx, self.gw.cy, t, cost);
        c.scale(self.alpha);
        c.axpy(1.0 - self.alpha, self.feat);
        c
    }

    /// Fused objective at `T`.
    pub fn objective(&self, t: &Mat, cost: GroundCost) -> f64 {
        let gw_term = tensor_product(self.gw.cx, self.gw.cy, t, cost).frob_inner(t);
        self.alpha * gw_term + (1.0 - self.alpha) * self.feat.frob_inner(t)
    }
}

/// Dense Algorithm-1 loop with the fused cost.
fn fgw_alg1(
    p: &FgwProblem,
    cost: GroundCost,
    reg: Regularizer,
    cfg: &Alg1Config,
) -> DenseGwResult {
    let mut t = Mat::outer(p.gw.a, p.gw.b);
    let mut converged = false;
    let mut outer = 0;
    for _ in 0..cfg.outer_iters {
        let c = p.fused_cost(&t, cost);
        let k = match reg {
            Regularizer::Proximal => super::alg1::stabilized_kernel(&c, Some(&t), cfg.epsilon),
            Regularizer::Entropy => super::alg1::stabilized_kernel(&c, None, cfg.epsilon),
        };
        let res = sinkhorn(p.gw.a, p.gw.b, &k, cfg.inner_iters, 0.0);
        outer += 1;
        if cfg.tol > 0.0 {
            let mut diff = 0.0;
            for (x, y) in res.plan.data().iter().zip(t.data()) {
                let d = x - y;
                diff += d * d;
            }
            t = res.plan;
            if diff.sqrt() < cfg.tol {
                converged = true;
                break;
            }
        } else {
            t = res.plan;
        }
    }
    let value = p.objective(&t, cost);
    DenseGwResult { value, plan: t, outer_iters: outer, converged }
}

/// Entropic fused GW.
pub fn egw_fgw(p: &FgwProblem, cost: GroundCost, cfg: &Alg1Config) -> DenseGwResult {
    fgw_alg1(p, cost, Regularizer::Entropy, cfg)
}

/// Proximal fused GW — the FGW accuracy benchmark.
pub fn pga_fgw(p: &FgwProblem, cost: GroundCost, cfg: &Alg1Config) -> DenseGwResult {
    fgw_alg1(p, cost, Regularizer::Proximal, cfg)
}

/// EMD-FGW: exact inner OT, ε = 0.
pub fn emd_fgw(p: &FgwProblem, cost: GroundCost, cfg: &Alg1Config) -> DenseGwResult {
    let mut t = Mat::outer(p.gw.a, p.gw.b);
    let mut outer = 0;
    let mut converged = false;
    for _ in 0..cfg.outer_iters {
        let c = p.fused_cost(&t, cost);
        let res = emd(p.gw.a, p.gw.b, &c);
        outer += 1;
        if cfg.tol > 0.0 {
            let mut diff = 0.0;
            for (x, y) in res.plan.data().iter().zip(t.data()) {
                let d = x - y;
                diff += d * d;
            }
            t = res.plan;
            if diff.sqrt() < cfg.tol {
                converged = true;
                break;
            }
        } else {
            t = res.plan;
        }
    }
    let value = p.objective(&t, cost);
    DenseGwResult { value, plan: t, outer_iters: outer, converged }
}

/// The naive baseline `T = a bᵀ` evaluated on the fused objective.
pub fn naive_fgw(p: &FgwProblem, cost: GroundCost) -> f64 {
    let t = Mat::outer(p.gw.a, p.gw.b);
    p.objective(&t, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::util::uniform;

    fn relation(n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let pts: Vec<[f64; 2]> = (0..n).map(|_| [rng.f64(), rng.f64()]).collect();
        Mat::from_fn(n, n, |i, j| {
            crate::linalg::sqdist(&pts[i], &pts[j]).sqrt()
        })
    }

    #[test]
    fn alpha_one_recovers_gw() {
        let n = 8;
        let c1 = relation(n, 1);
        let c2 = relation(n, 2);
        let a = uniform(n);
        let feat = Mat::full(n, n, 5.0);
        let gw = GwProblem::new(&c1, &c2, &a, &a);
        let p = FgwProblem::new(gw, &feat, 1.0);
        let cfg = Alg1Config::default();
        let fused = pga_fgw(&p, GroundCost::L2, &cfg);
        let plain = super::super::alg1::pga_gw(&gw, GroundCost::L2, &cfg);
        assert!(
            (fused.value - plain.value).abs() < 1e-9,
            "fgw(α=1) {} vs gw {}",
            fused.value,
            plain.value
        );
    }

    #[test]
    fn alpha_zero_recovers_wasserstein() {
        // α = 0: objective is ⟨M, T⟩ minimized over the polytope — compare
        // against the exact OT cost.
        let n = 6;
        let c1 = relation(n, 3);
        let c2 = relation(n, 4);
        let a = uniform(n);
        let feat = Mat::from_fn(n, n, |i, j| ((i as f64) - (j as f64)).powi(2));
        let gw = GwProblem::new(&c1, &c2, &a, &a);
        let p = FgwProblem::new(gw, &feat, 0.0);
        let cfg = Alg1Config { epsilon: 1e-3, outer_iters: 5, inner_iters: 2000, tol: 0.0 };
        let fused = egw_fgw(&p, GroundCost::L2, &cfg);
        let exact = emd(&a, &a, &feat);
        assert!(
            (fused.value - exact.cost).abs() < 0.05 * (1.0 + exact.cost),
            "fgw(α=0) {} vs W {}",
            fused.value,
            exact.cost
        );
    }

    #[test]
    fn objective_interpolates() {
        // Naive plan: objective is exactly the α-interpolation of the parts.
        let n = 5;
        let c1 = relation(n, 5);
        let c2 = relation(n, 6);
        let a = uniform(n);
        let feat = Mat::from_fn(n, n, |i, j| (i + j) as f64 * 0.1);
        let gw = GwProblem::new(&c1, &c2, &a, &a);
        let t = Mat::outer(&a, &a);
        let gw_part = tensor_product(&c1, &c2, &t, GroundCost::L2).frob_inner(&t);
        let w_part = feat.frob_inner(&t);
        for &alpha in &[0.0, 0.3, 0.6, 1.0] {
            let p = FgwProblem::new(gw, &feat, alpha);
            let v = p.objective(&t, GroundCost::L2);
            let expect = alpha * gw_part + (1.0 - alpha) * w_part;
            assert!((v - expect).abs() < 1e-10);
        }
    }

    #[test]
    fn fused_beats_naive() {
        let n = 8;
        let c1 = relation(n, 7);
        let c2 = relation(n, 8);
        let a = uniform(n);
        let feat = Mat::from_fn(n, n, |i, j| ((i as f64 * 0.9) - j as f64).abs());
        let gw = GwProblem::new(&c1, &c2, &a, &a);
        let p = FgwProblem::new(gw, &feat, 0.6);
        let cfg = Alg1Config { epsilon: 0.01, outer_iters: 40, inner_iters: 80, tol: 1e-10 };
        let opt = pga_fgw(&p, GroundCost::L2, &cfg);
        let naive = naive_fgw(&p, GroundCost::L2);
        assert!(opt.value <= naive + 1e-9, "opt {} vs naive {naive}", opt.value);
    }
}
