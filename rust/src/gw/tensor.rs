//! The tensor-matrix product `C(T) = L(Cx, Cy) ⊗ T` — the computational
//! bottleneck of Algorithm 1 (§2.3) — in three regimes:
//!
//! * [`tensor_product_generic`] — arbitrary cost, O(m²n²). This is what
//!   makes dense GW with ℓ1 cost intractable and motivates the paper.
//! * [`tensor_product_decomposable`] — the Peyré et al. (2016) fast path,
//!   O(n²m + m²n), only for decomposable costs.
//! * [`SparseCostContext`] — the gathered s×s form used by Spar-GW: after
//!   sampling the index set `S`, only the s·s ground-cost values
//!   `L(Cx[i_l, i_{l'}], Cy[j_l, j_{l'}])` ever enter the computation.
//!
//! `SparseCostContext` pre-gathers the `n×s` column slices `Cx[:, idx_i]`
//! and `Cy[:, idx_j]` once per solve so each outer iteration streams
//! contiguous rows (a §Perf optimization over per-element gathers).

use super::cost::GroundCost;
use crate::kernel::simd;
use crate::kernel::Scalar;
use crate::linalg::Mat;
use crate::runtime::pool::pool;

/// Generic tensor product: `C(T)[i,j] = Σ_{i',j'} L(Cx[i,i'], Cy[j,j']) T[i',j']`.
/// O(m²n²) time — use only for validation and the dense ℓ1 baselines.
/// Parallel over output-row chunks (each `(i, j)` keeps its serial
/// reduction order, so results are thread-count-free).
pub fn tensor_product_generic(cx: &Mat, cy: &Mat, t: &Mat, cost: GroundCost) -> Mat {
    let m = cx.rows();
    let n = cy.rows();
    assert_eq!(t.shape(), (m, n));
    let mut out = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return out;
    }
    // Each output row costs m·n cost-evals; a single row is almost
    // always past the grain, so chunk at one row minimum.
    pool().for_each_row_chunk_mut(out.data_mut(), n, 1, |orows, range, _| {
        for (local, i) in range.enumerate() {
            let cx_row = cx.row(i);
            let orow = &mut orows[local * n..(local + 1) * n];
            for (j, o) in orow.iter_mut().enumerate() {
                let cy_row = cy.row(j);
                let mut acc = 0.0;
                for ip in 0..m {
                    let x = cx_row[ip];
                    let t_row = t.row(ip);
                    // Inner loop over j' — contiguous in both t and cy_row.
                    let mut s = 0.0;
                    for jp in 0..n {
                        s += cost.eval(x, cy_row[jp]) * t_row[jp];
                    }
                    acc += s;
                }
                *o = acc;
            }
        }
    });
    out
}

/// Decomposable fast path (Prop. 1 of Peyré et al. 2016):
/// `C(T) = f1(Cx)·r·1ᵀ + 1·(f2(Cy)·c)ᵀ − h1(Cx)·T·h2(Cy)ᵀ`
/// with `r = T1`, `c = Tᵀ1`. O(n²m + m²n).
pub fn tensor_product_decomposable(cx: &Mat, cy: &Mat, t: &Mat, cost: GroundCost) -> Mat {
    let d = cost
        .decomposition()
        .expect("cost is not decomposable; use tensor_product_generic");
    let m = cx.rows();
    let n = cy.rows();
    assert_eq!(t.shape(), (m, n));
    let r = t.row_sums();
    let c = t.col_sums();

    // term1[i] = Σ_{i'} f1(Cx[i,i']) r[i']
    let f1cx = cx.map(d.f1);
    let term1 = f1cx.matvec(&r);
    // term2[j] = Σ_{j'} f2(Cy[j,j']) c[j']
    let f2cy = cy.map(d.f2);
    let term2 = f2cy.matvec(&c);
    // term3 = h1(Cx) · T · h2(Cy)ᵀ
    let h1cx = cx.map(d.h1);
    let h2cy = cy.map(d.h2);
    let term3 = h1cx.matmul(t).matmul(&h2cy.transpose());

    let mut out = Mat::zeros(m, n);
    for i in 0..m {
        let t1 = term1[i];
        let row = out.row_mut(i);
        let t3row = term3.row(i);
        for j in 0..n {
            row[j] = t1 + term2[j] - t3row[j];
        }
    }
    out
}

/// Dispatch: decomposable fast path when available, generic otherwise.
pub fn tensor_product(cx: &Mat, cy: &Mat, t: &Mat, cost: GroundCost) -> Mat {
    if cost.is_decomposable() {
        tensor_product_decomposable(cx, cy, t, cost)
    } else {
        tensor_product_generic(cx, cy, t, cost)
    }
}

/// GW energy `E(T) = ⟨L(Cx,Cy) ⊗ T, T⟩`.
pub fn gw_energy(cx: &Mat, cy: &Mat, t: &Mat, cost: GroundCost) -> f64 {
    tensor_product(cx, cy, t, cost).frob_inner(t)
}

/// Pre-gathered context for the O(s²) sparse cost products of Algorithm 2.
///
/// The gathered relation values are constant across outer iterations, so
/// the elementwise ground cost is applied ONCE at construction:
/// `l_g[l, l'] = L(Cx[i_l, i_{l'}], Cy[j_l, j_{l'}])`. Every iteration's
/// step 6a then reduces to the plain matvec `c = l_g · t` — one contiguous
/// s×s stream instead of two plus a transform (≈2× less memory traffic on
/// this memory-bound loop; see EXPERIMENTS.md §Perf iteration 1).
pub struct SparseCostContext {
    /// Precomputed elementwise costs on S×S, s×s row-major, stored as f32:
    /// the loop is memory-bandwidth-bound, so halving the element width is
    /// ~2× per-iteration throughput; accumulation stays in f64 so the
    /// reduction loses only the f32 rounding of the *inputs* (≈1e-7
    /// relative — far below the sampling noise of the estimator).
    l_g: Vec<f32>,
    s: usize,
}

/// Minimum gathered entries per parallel chunk of the O(s²) loops (the
/// cost-row product and the context build): each output row touches `s`
/// gathered values, so chunks hold at least `2^14 / s` rows. Measured
/// crossover on the bench box: below ~16k entries per chunk the pool's
/// dispatch hand-off costs more than the chunk computes; the historical
/// comment claimed the same number while the code gated on a flat 64
/// rows per thread, which over-chunked small-`s` problems.
pub const MIN_GATHERED_ENTRIES_PER_CHUNK: usize = 1 << 14;

impl SparseCostContext {
    /// Gather the relation values touched by the index set `S` and apply
    /// the ground cost. O(s²) time and memory — the same order as one
    /// sparse cost product, and (with the dense Eq. (5) factor build)
    /// the dominant preprocessing phase for large inputs; runs parallel
    /// over row chunks on the crate-wide pool (each row is an
    /// independent gather, so results are thread-count-free).
    pub fn new(cx: &Mat, cy: &Mat, idx_i: &[usize], idx_j: &[usize], cost: GroundCost) -> Self {
        assert_eq!(idx_i.len(), idx_j.len());
        let s = idx_i.len();
        let mut l_g = vec![0f32; s * s];
        if s > 0 {
            let min_rows = MIN_GATHERED_ENTRIES_PER_CHUNK.div_ceil(s);
            pool().for_each_row_chunk_mut(&mut l_g, s, min_rows, |rows_chunk, range, _| {
                for (local, l) in range.enumerate() {
                    let cx_row = cx.row(idx_i[l]);
                    let cy_row = cy.row(idx_j[l]);
                    let out = &mut rows_chunk[local * s..(local + 1) * s];
                    // Branch-free specializations vectorize; the generic
                    // path calls through eval().
                    match cost {
                        GroundCost::L1 => {
                            for lp in 0..s {
                                out[lp] = (cx_row[idx_i[lp]] - cy_row[idx_j[lp]]).abs() as f32;
                            }
                        }
                        GroundCost::L2 => {
                            for lp in 0..s {
                                let d = cx_row[idx_i[lp]] - cy_row[idx_j[lp]];
                                out[lp] = (d * d) as f32;
                            }
                        }
                        cost => {
                            for lp in 0..s {
                                out[lp] = cost.eval(cx_row[idx_i[lp]], cy_row[idx_j[lp]]) as f32;
                            }
                        }
                    }
                }
            });
        }
        SparseCostContext { l_g, s }
    }

    pub fn s(&self) -> usize {
        self.s
    }

    /// Fill `out[0..len]` with the cost-product rows `base..base+len`.
    /// The shared kernel behind the serial and row-chunked parallel entry
    /// points, generic over the plan-value scalar: each row reduces
    /// through [`Scalar::gathered_dot_backend`] — at f64 the historical
    /// 4-lane f64 schedule (bit-identical), at f32 the 8-lane
    /// block-folded form. The SIMD backend is passed in by the entry
    /// points (resolved once on the submitting thread — the
    /// capture-at-submit rule; this body runs inside pool chunks, which
    /// never see the caller's thread-local override). Each output row is
    /// independent, so chunking does not change results bit-wise.
    fn fill_cost_rows<S: Scalar>(
        &self,
        backend: simd::Backend,
        policy: simd::NumericsPolicy,
        t_vals: &[S],
        out: &mut [S],
        base: usize,
    ) {
        let s = self.s;
        for (off, o) in out.iter_mut().enumerate() {
            let l = base + off;
            let row = &self.l_g[l * s..(l + 1) * s];
            *o = S::from_f64(S::gathered_dot_backend(backend, policy, row, t_vals));
        }
    }

    /// Sparse cost product into a caller-provided buffer:
    /// `out[l] = Σ_{l'} L(cx_g[l,l'], cy_g[l,l']) · t[l']`.
    /// O(s²), zero allocations — the per-iteration hot loop of
    /// Algorithm 2 (step 6a) as driven by the [`SparCore`
    /// engine](crate::gw::core).
    pub fn cost_values_into<S: Scalar>(&self, t_vals: &[S], out: &mut [S]) {
        assert_eq!(
            t_vals.len(),
            self.s,
            "SparseCostContext::cost_values_into: t length {} != s {}",
            t_vals.len(),
            self.s
        );
        assert_eq!(
            out.len(),
            self.s,
            "SparseCostContext::cost_values_into: out length {} != s {}",
            out.len(),
            self.s
        );
        self.fill_cost_rows(simd::current(), simd::current_numerics(), t_vals, out, 0);
    }

    /// Row-chunked parallel cost product on the crate-wide persistent
    /// pool. Each chunk owns a disjoint range of output rows over the
    /// shared read-only cost block, so the result is bit-identical to
    /// the serial path for every thread count. Gated on **gathered
    /// entries per chunk**: a chunk of `r` rows streams `r·s` gathered
    /// values, and chunks below [`MIN_GATHERED_ENTRIES_PER_CHUNK`]
    /// (~2^14, the measured pool-dispatch crossover) run inline serial.
    pub fn cost_values_into_threaded<S: Scalar>(&self, t_vals: &[S], out: &mut [S]) {
        assert_eq!(t_vals.len(), self.s);
        assert_eq!(out.len(), self.s);
        if self.s == 0 {
            return;
        }
        let min_rows = MIN_GATHERED_ENTRIES_PER_CHUNK.div_ceil(self.s);
        let backend = simd::current();
        let policy = simd::current_numerics();
        pool().for_each_chunk_mut(out, min_rows, |chunk, range, _| {
            self.fill_cost_rows(backend, policy, t_vals, chunk, range.start);
        });
    }

    /// Sparse cost product, allocating form (kept for one-shot callers;
    /// the solver loop uses [`SparseCostContext::cost_values_into`]).
    pub fn cost_values<S: Scalar>(&self, t_vals: &[S]) -> Vec<S> {
        let mut out = vec![S::ZERO; self.s];
        self.cost_values_into(t_vals, &mut out);
        out
    }

    /// The sparse GW estimate of Algorithm 2 step 8:
    /// `ĜW = Σ_{l,l'} L(cx_g[l,l'], cy_g[l,l']) t[l] t[l']`.
    /// The final reduction always runs in f64 (the reported GW cost stays
    /// full-precision in f32 mode).
    pub fn energy<S: Scalar>(&self, t_vals: &[S]) -> f64 {
        let c = self.cost_values(t_vals);
        c.iter().zip(t_vals).map(|(ci, ti)| ci.to_f64() * ti.to_f64()).sum()
    }

    /// [`SparseCostContext::energy`] with a caller-provided scratch buffer
    /// (length s) — allocation-free, bit-identical to the allocating form.
    pub fn energy_with<S: Scalar>(&self, t_vals: &[S], scratch: &mut [S]) -> f64 {
        self.cost_values_into(t_vals, scratch);
        scratch.iter().zip(t_vals).map(|(ci, ti)| ci.to_f64() * ti.to_f64()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn random_sym(n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.f64() + 0.05;
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        m
    }

    fn random_plan(m: usize, n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let mut t = Mat::from_fn(m, n, |_, _| rng.f64());
        let total = t.sum();
        t.scale(1.0 / total);
        t
    }

    #[test]
    fn decomposable_matches_generic_l2() {
        let cx = random_sym(6, 1);
        let cy = random_sym(5, 2);
        let t = random_plan(6, 5, 3);
        let g = tensor_product_generic(&cx, &cy, &t, GroundCost::L2);
        let d = tensor_product_decomposable(&cx, &cy, &t, GroundCost::L2);
        for i in 0..6 {
            for j in 0..5 {
                assert!(
                    (g[(i, j)] - d[(i, j)]).abs() < 1e-10,
                    "mismatch at ({i},{j}): {} vs {}",
                    g[(i, j)],
                    d[(i, j)]
                );
            }
        }
    }

    #[test]
    fn decomposable_matches_generic_kl() {
        let cx = random_sym(4, 4);
        let cy = random_sym(4, 5);
        let t = random_plan(4, 4, 6);
        let g = tensor_product_generic(&cx, &cy, &t, GroundCost::Kl);
        let d = tensor_product_decomposable(&cx, &cy, &t, GroundCost::Kl);
        for i in 0..4 {
            for j in 0..4 {
                assert!((g[(i, j)] - d[(i, j)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn energy_zero_for_identical_spaces() {
        // Cx == Cy and T = identity/ n ⇒ every L(Cx[i,i'],Cy[j,j']) picked
        // by the plan pairs identical entries ⇒ E = 0.
        let c = random_sym(5, 7);
        let n = 5;
        let mut t = Mat::zeros(n, n);
        for i in 0..n {
            t[(i, i)] = 1.0 / n as f64;
        }
        for cost in [GroundCost::L1, GroundCost::L2] {
            let e = gw_energy(&c, &c, &t, cost);
            assert!(e.abs() < 1e-12, "{cost:?}: {e}");
        }
    }

    #[test]
    fn sparse_context_matches_dense_on_full_grid() {
        // With S = the full index grid, the sparse product equals the dense
        // tensor product read off at the grid points.
        let m = 4;
        let n = 3;
        let cx = random_sym(m, 8);
        let cy = random_sym(n, 9);
        let t = random_plan(m, n, 10);
        // Full grid in row-major order.
        let mut idx_i = Vec::new();
        let mut idx_j = Vec::new();
        let mut t_vals = Vec::new();
        for i in 0..m {
            for j in 0..n {
                idx_i.push(i);
                idx_j.push(j);
                t_vals.push(t[(i, j)]);
            }
        }
        for cost in [GroundCost::L1, GroundCost::L2, GroundCost::Kl] {
            let ctx = SparseCostContext::new(&cx, &cy, &idx_i, &idx_j, cost);
            let c_sparse = ctx.cost_values(&t_vals);
            let c_dense = tensor_product_generic(&cx, &cy, &t, cost);
            for (l, (&i, &j)) in idx_i.iter().zip(&idx_j).enumerate() {
                // f32 storage of the gathered cost block: inputs round
                // at ~1e-7 relative; the f64 accumulation adds nothing.
                let tol = 3e-6 * c_dense[(i, j)].abs().max(1.0);
                assert!(
                    (c_sparse[l] - c_dense[(i, j)]).abs() < tol,
                    "{cost:?} at l={l}: {} vs {}",
                    c_sparse[l],
                    c_dense[(i, j)]
                );
            }
            // Energy agrees too (f32-input rounding tolerance).
            let e_sparse = ctx.energy(&t_vals);
            let e_dense = c_dense.frob_inner(&t);
            assert!(
                (e_sparse - e_dense).abs() < 3e-6 * e_dense.abs().max(1.0),
                "{cost:?}: energy {e_sparse} vs {e_dense}"
            );
        }
    }

    #[test]
    fn f32_cost_product_tracks_f64() {
        let n = 20;
        let cx = random_sym(n, 21);
        let cy = random_sym(n, 22);
        let mut rng = Xoshiro256::new(23);
        let s = 8 * n;
        let idx_i: Vec<usize> = (0..s).map(|_| rng.usize(n)).collect();
        let idx_j: Vec<usize> = (0..s).map(|_| rng.usize(n)).collect();
        let t64: Vec<f64> = (0..s).map(|_| rng.f64() * 1e-3).collect();
        let t32: Vec<f32> = t64.iter().map(|&x| x as f32).collect();
        let ctx = SparseCostContext::new(&cx, &cy, &idx_i, &idx_j, GroundCost::L1);
        let c64 = ctx.cost_values(&t64);
        let c32 = ctx.cost_values(&t32);
        for (l, (a, b)) in c32.iter().zip(&c64).enumerate() {
            let d = (*a as f64 - b).abs();
            assert!(d < 1e-4 * b.abs().max(1e-6), "l={l}: {a} vs {b}");
        }
        let e64 = ctx.energy(&t64);
        let e32 = ctx.energy(&t32);
        assert!(
            (e64 - e32).abs() < 1e-4 * e64.abs().max(1e-9),
            "energy {e32} vs {e64}"
        );
    }

    #[test]
    fn threaded_cost_product_bit_identical_to_serial() {
        let n = 40;
        let cx = random_sym(n, 11);
        let cy = random_sym(n, 12);
        let mut rng = Xoshiro256::new(13);
        let s = 6 * n;
        let idx_i: Vec<usize> = (0..s).map(|_| rng.usize(n)).collect();
        let idx_j: Vec<usize> = (0..s).map(|_| rng.usize(n)).collect();
        let t_vals: Vec<f64> = (0..s).map(|_| rng.f64()).collect();
        let ctx = SparseCostContext::new(&cx, &cy, &idx_i, &idx_j, GroundCost::L1);
        let serial = ctx.cost_values(&t_vals);
        for limit in [1usize, 2, 3, 7] {
            crate::runtime::pool::with_thread_limit(limit, || {
                let mut out = vec![0.0; s];
                ctx.cost_values_into_threaded(&t_vals, &mut out);
                assert_eq!(out, serial, "thread limit = {limit}");
            });
        }
        // energy_with matches energy exactly.
        let mut scratch = vec![0.0; s];
        assert_eq!(ctx.energy_with(&t_vals, &mut scratch), ctx.energy(&t_vals));
    }
}
