//! AE — Anchor Energy distance (Sato, Cuturi, Yamada & Kashima 2020),
//! the O(n² log n²) comparator of Tables 2–3.
//!
//! Simplified reimplementation (documented in DESIGN.md §4): each point is
//! summarized by the empirical distribution of its relation-matrix row
//! (its "anchor view"); the pairwise point cost is the 1-D Wasserstein
//! distance between those row distributions (computable from sorted rows /
//! quantiles in linear time), and the final value couples the points by an
//! exact OT on that cost. ℓ1 row-costs give W1 between quantile functions;
//! ℓ2 gives the squared-quantile version.

use std::time::Instant;

use super::core::Workspace;
use super::cost::GroundCost;
use super::solver::{GwSolver, Opts, PhaseTimings, Plan, SolveReport, SolverBase};
use super::GwProblem;
use crate::linalg::Mat;
use crate::ot::emd;
use crate::rng::Rng;
use crate::runtime::pool::pool;
use crate::util::error::Result;

/// Configuration for AE.
#[derive(Clone, Copy, Debug)]
pub struct AnchorConfig {
    /// Number of quantiles summarizing each row distribution
    /// (0 → min(n, 64)).
    pub quantiles: usize,
}

impl Default for AnchorConfig {
    fn default() -> Self {
        AnchorConfig { quantiles: 0 }
    }
}

/// Quantile summary of each row of a relation matrix: one contiguous n×q
/// matrix whose row i holds q evenly spaced order statistics of the
/// sorted row i of `c`. Rows are independent, so the fill runs as
/// row-aligned chunks on the worker pool (bit-identical at any width; the
/// per-row sort + lerp is unchanged from the historical nested-Vec form).
fn row_quantiles(c: &Mat, q: usize) -> Mat {
    let n = c.rows();
    let mut out = Mat::zeros(n, q);
    pool().for_each_row_chunk_mut(out.data_mut(), q, 8, |chunk, range, _| {
        for (bi, i) in range.enumerate() {
            let mut row = c.row(i).to_vec();
            row.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let qrow = &mut chunk[bi * q..(bi + 1) * q];
            for (k, slot) in qrow.iter_mut().enumerate() {
                // mid-point quantile positions
                let pos = (k as f64 + 0.5) / q as f64 * (row.len() as f64 - 1.0);
                let lo = pos.floor() as usize;
                let hi = pos.ceil() as usize;
                let frac = pos - lo as f64;
                *slot = row[lo] * (1.0 - frac) + row[hi] * frac;
            }
        }
    });
    out
}

/// AE distance plus the optimal point coupling on the anchor cost.
pub fn anchor_solve(p: &GwProblem, cost: GroundCost, cfg: &AnchorConfig) -> (f64, Mat) {
    let (m, n) = (p.m(), p.n());
    let q = if cfg.quantiles == 0 { m.min(n).min(64) } else { cfg.quantiles };
    let qx = row_quantiles(p.cx, q);
    let qy = row_quantiles(p.cy, q);
    // Point-pair cost: 1-D OT between quantile functions.
    let e = Mat::from_fn(m, n, |i, j| {
        let (xi, yj) = (qx.row(i), qy.row(j));
        let mut s = 0.0;
        for k in 0..q {
            s += cost.eval(xi[k], yj[k]);
        }
        s / q as f64
    });
    let r = emd(p.a, p.b, &e);
    (r.cost, r.plan)
}

/// AE distance between two metric-measure spaces (thin wrapper over
/// [`anchor_solve`], keeping the historical value-only API).
pub fn anchor_energy(p: &GwProblem, cost: GroundCost, cfg: &AnchorConfig) -> f64 {
    anchor_solve(p, cost, cfg).0
}

/// Registry solver for the anchor-energy distance (`"anchor"`). One-shot
/// exact method: `outer_iters = 1`, `converged = true`, plan = the exact
/// OT coupling on the anchor cost.
pub struct AnchorSolver {
    /// Row-cost used to compare quantile functions.
    pub cost: GroundCost,
    /// Quantile summary size.
    pub cfg: AnchorConfig,
}

impl AnchorSolver {
    pub(crate) fn from_opts(base: &SolverBase, o: &mut Opts) -> Result<Self> {
        o.precision_f64_only("anchor", base.precision)?;
        Ok(AnchorSolver {
            cost: o.cost(base.cost)?,
            cfg: AnchorConfig { quantiles: o.usize("quantiles", 0)? },
        })
    }
}

impl GwSolver for AnchorSolver {
    fn name(&self) -> &'static str {
        "anchor"
    }

    fn solve(&self, p: &GwProblem, _rng: &mut Rng, _ws: &mut Workspace) -> Result<SolveReport> {
        let t0 = Instant::now();
        let (value, plan) = anchor_solve(p, self.cost, &self.cfg);
        Ok(SolveReport {
            solver: self.name(),
            value,
            plan: Plan::Dense(plan),
            outer_iters: 1,
            converged: true,
            timings: PhaseTimings::basic(0.0, t0.elapsed().as_secs_f64()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::util::uniform;

    fn relation(n: usize, seed: u64, scale: f64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let pts: Vec<[f64; 2]> = (0..n)
            .map(|_| [rng.f64() * scale, rng.f64() * scale])
            .collect();
        Mat::from_fn(n, n, |i, j| crate::linalg::sqdist(&pts[i], &pts[j]).sqrt())
    }

    #[test]
    fn zero_for_identical_spaces() {
        let n = 10;
        let c = relation(n, 1, 1.0);
        let a = uniform(n);
        let p = GwProblem::new(&c, &c, &a, &a);
        for cost in [GroundCost::L1, GroundCost::L2] {
            let v = anchor_energy(&p, cost, &AnchorConfig::default());
            assert!(v.abs() < 1e-9, "{cost:?}: {v}");
        }
    }

    #[test]
    fn permutation_invariant() {
        let n = 9;
        let c = relation(n, 2, 1.0);
        let perm = [4, 2, 7, 0, 8, 1, 6, 3, 5];
        let cp = Mat::from_fn(n, n, |i, j| c[(perm[i], perm[j])]);
        let a = uniform(n);
        let p = GwProblem::new(&c, &cp, &a, &a);
        let v = anchor_energy(&p, GroundCost::L1, &AnchorConfig::default());
        assert!(v.abs() < 1e-9, "AE after permutation: {v}");
    }

    #[test]
    fn separates_different_scales() {
        let n = 10;
        let c1 = relation(n, 3, 1.0);
        let c2 = relation(n, 3, 5.0); // same shape, 5× scale
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let v = anchor_energy(&p, GroundCost::L1, &AnchorConfig::default());
        assert!(v > 0.5, "AE across scales: {v}");
    }

    #[test]
    fn triangle_like_monotonicity() {
        // AE to a slightly perturbed copy < AE to a heavily perturbed copy.
        let n = 12;
        let c = relation(n, 4, 1.0);
        let mut small = c.clone();
        let mut big = c.clone();
        small.map_inplace(|v| v * 1.05);
        big.map_inplace(|v| v * 3.0);
        let a = uniform(n);
        let ps = GwProblem::new(&c, &small, &a, &a);
        let pb = GwProblem::new(&c, &big, &a, &a);
        let vs = anchor_energy(&ps, GroundCost::L1, &AnchorConfig::default());
        let vb = anchor_energy(&pb, GroundCost::L1, &AnchorConfig::default());
        assert!(vs < vb, "small {vs} vs big {vb}");
    }
}
