//! SaGroW — Sampled Gromov-Wasserstein (Kerdoncuff, Emonet & Sebban 2021),
//! the closest prior-art comparator in Table 1 (O(n²(s′ + log n))).
//!
//! Where Spar-GW sparsifies the *coupling*, SaGroW keeps the coupling dense
//! and instead estimates the gradient / cost matrix stochastically: at each
//! outer iteration it samples `s′` index pairs `(i′, j′) ∼ T⁽ʳ⁾` and
//! averages the corresponding slices of the loss tensor,
//!   `Ĉ[i,j] = (1/s′) Σ_k L(Cx[i, i′_k], Cy[j, j′_k])`,
//! an unbiased estimate of `L ⊗ T̄` (T̄ = T normalized to total mass 1),
//! then performs the same KL-proximal Sinkhorn step as PGA-GW. For a fair
//! comparison the paper sets `s′ = s²/n²` so both methods touch the same
//! number of tensor entries per iteration.

use std::time::Instant;

use super::core::Workspace;
use super::cost::GroundCost;
use super::fgw::FgwProblem;
use super::solver::{GwSolver, Opts, PhaseTimings, Plan, SolveReport, SolverBase};
use super::tensor::tensor_product;
use super::ugw::{ugw_objective, unbalanced_cost_shift, UgwConfig, UgwResult};
use super::{DenseGwResult, GwProblem, Regularizer};
use crate::linalg::Mat;
use crate::ot::{sinkhorn, unbalanced_sinkhorn};
use crate::rng::{AliasTable, Rng};
use crate::util::error::Result;

/// Configuration for SaGroW.
#[derive(Clone, Copy, Debug)]
pub struct SagrowConfig {
    /// Regularization weight ε.
    pub epsilon: f64,
    /// Number of sampled tensor slices s′ per iteration.
    pub s_prime: usize,
    /// Outer iterations R.
    pub outer_iters: usize,
    /// Inner Sinkhorn iterations H.
    pub inner_iters: usize,
    /// Regularizer (paper uses KL-proximal for SaGroW, as for Spar-GW).
    pub reg: Regularizer,
    /// Outer stopping tolerance (0 disables).
    pub tol: f64,
}

impl Default for SagrowConfig {
    fn default() -> Self {
        SagrowConfig {
            epsilon: 0.01,
            s_prime: 16,
            outer_iters: 20,
            inner_iters: 50,
            reg: Regularizer::Proximal,
            tol: 1e-9,
        }
    }
}

/// Sample `s′` tensor slices `(i′, j′) ∼ T` (flattened categorical) and
/// average them into the stochastic cost estimate
/// `Ĉ[i,j] = (1/s′) Σ_k L(Cx[i, i′_k], Cy[j, j′_k])` — an unbiased
/// estimate of `L ⊗ T̄` with `T̄ = T / m(T)`.
fn sampled_cost(
    p: &GwProblem,
    t: &Mat,
    cost: GroundCost,
    s_prime: usize,
    rng: &mut Rng,
) -> Mat {
    let (m, n) = (p.m(), p.n());
    let alias = AliasTable::new(t.data());
    let mut c_hat = Mat::zeros(m, n);
    for _ in 0..s_prime {
        let key = alias.sample(rng);
        let (ip, jp) = (key / n, key % n);
        // Accumulate the (i′,j′) slice: L(Cx[i,i′], Cy[j,j′]).
        for i in 0..m {
            let x = p.cx[(i, ip)];
            let row = c_hat.row_mut(i);
            for j in 0..n {
                row[j] += cost.eval(x, p.cy[(j, jp)]);
            }
        }
    }
    c_hat.scale(1.0 / s_prime as f64);
    c_hat
}

/// Run SaGroW on a balanced GW problem.
pub fn sagrow(p: &GwProblem, cost: GroundCost, cfg: &SagrowConfig, rng: &mut Rng) -> DenseGwResult {
    sagrow_inner(p, None, cost, cfg, rng)
}

/// SaGroW adapted to the fused GW objective (Fig. 6 / Tables 2–3 comparator):
/// the stochastic structural cost is blended with the feature distances,
/// `Ĉ_fu = α Ĉ + (1−α) M`, exactly as Algorithm 4 fuses the sparse cost.
pub fn sagrow_fgw(
    p: &FgwProblem,
    cost: GroundCost,
    cfg: &SagrowConfig,
    rng: &mut Rng,
) -> DenseGwResult {
    sagrow_inner(&p.gw, Some((p.feat, p.alpha)), cost, cfg, rng)
}

fn sagrow_inner(
    p: &GwProblem,
    fused: Option<(&Mat, f64)>,
    cost: GroundCost,
    cfg: &SagrowConfig,
    rng: &mut Rng,
) -> DenseGwResult {
    let s_prime = cfg.s_prime.max(1);
    let mut t = Mat::outer(p.a, p.b);
    let mut outer = 0;
    let mut converged = false;

    for _ in 0..cfg.outer_iters {
        let mut c_hat = sampled_cost(p, &t, cost, s_prime, rng);
        if let Some((feat, alpha)) = fused {
            // Ĉ_fu = α Ĉ + (1−α) M.
            c_hat.scale(alpha);
            c_hat.axpy(1.0 - alpha, feat);
        }

        // KL-proximal (or entropic) Sinkhorn step (stabilized kernel).
        let k = match cfg.reg {
            Regularizer::Proximal => {
                super::alg1::stabilized_kernel(&c_hat, Some(&t), cfg.epsilon)
            }
            Regularizer::Entropy => super::alg1::stabilized_kernel(&c_hat, None, cfg.epsilon),
        };
        let res = sinkhorn(p.a, p.b, &k, cfg.inner_iters, 0.0);
        outer += 1;
        if cfg.tol > 0.0 {
            let mut diff = 0.0;
            for (x, y) in res.plan.data().iter().zip(t.data()) {
                let d = x - y;
                diff += d * d;
            }
            t = res.plan;
            if diff.sqrt() < cfg.tol {
                converged = true;
                break;
            }
        } else {
            t = res.plan;
        }
    }

    // Final value: exact energy at the final plan (same convention as the
    // other dense methods so Fig. 2 error comparisons are apples-to-apples).
    let mut value = tensor_product(p.cx, p.cy, &t, cost).frob_inner(&t);
    if let Some((feat, alpha)) = fused {
        value = alpha * value + (1.0 - alpha) * feat.frob_inner(&t);
    }
    DenseGwResult { value, plan: t, outer_iters: outer, converged }
}

/// SaGroW adapted for unbalanced problems (the Fig. 3 comparator):
/// the dense PGA-UGW loop of §5.2 with the full tensor product replaced by
/// the stochastic slice estimate. Slices are drawn from `T⁽ʳ⁾/m(T⁽ʳ⁾)` and
/// the estimate rescaled by `m(T⁽ʳ⁾)` so it matches `L ⊗ T` in expectation.
pub fn sagrow_ugw(
    p: &GwProblem,
    cost: GroundCost,
    s_prime: usize,
    cfg: &UgwConfig,
    rng: &mut Rng,
) -> UgwResult {
    let (m, n) = (p.m(), p.n());
    let s_prime = s_prime.max(1);
    let ma: f64 = p.a.iter().sum();
    let mb: f64 = p.b.iter().sum();
    // T⁽⁰⁾ = a bᵀ / √(m(a)m(b)), as in the dense loop.
    let mut t = Mat::outer(p.a, p.b);
    t.scale(1.0 / (ma * mb).sqrt());
    let mut outer = 0;
    for _ in 0..cfg.outer_iters {
        let mass = t.sum();
        if mass <= 0.0 || !mass.is_finite() {
            break;
        }
        let eps_bar = cfg.epsilon * mass;
        let lam_bar = cfg.lambda * mass;
        // Ĉ ≈ L⊗T̄; L⊗T = m(T)·(L⊗T̄).
        let mut c_hat = sampled_cost(p, &t, cost, s_prime, rng);
        c_hat.scale(mass);
        let shift = unbalanced_cost_shift(&t.row_sums(), &t.col_sums(), p.a, p.b, cfg.lambda);
        // Proximal kernel K = exp(−C_un/ε̄) ⊙ T.
        let mut k = Mat::zeros(m, n);
        for i in 0..m {
            let crow = c_hat.row(i);
            let trow = t.row(i);
            let krow = k.row_mut(i);
            for j in 0..n {
                krow[j] = (-(crow[j] + shift) / eps_bar).exp() * trow[j];
            }
        }
        let mut t_next = unbalanced_sinkhorn(p.a, p.b, &k, lam_bar, eps_bar, cfg.inner_iters);
        let next_mass = t_next.sum();
        if !next_mass.is_finite() || next_mass <= 0.0 {
            // Kernel over/underflow (extreme λ/ε): keep the last good plan.
            break;
        }
        t_next.scale((mass / next_mass).sqrt());
        outer += 1;
        if cfg.tol > 0.0 {
            let mut diff = 0.0;
            for (x, y) in t_next.data().iter().zip(t.data()) {
                let d = x - y;
                diff += d * d;
            }
            t = t_next;
            if diff.sqrt() < cfg.tol {
                break;
            }
        } else {
            t = t_next;
        }
    }
    let value = ugw_objective(p, &t, cost, cfg.lambda);
    UgwResult { value, plan: t, outer_iters: outer }
}

/// The paper's sampling-budget match: `s′ = s²/n²` (so SaGroW touches the
/// same number of tensor elements as Spar-GW with `s` samples).
pub fn matched_s_prime(s: usize, m: usize, n: usize) -> usize {
    ((s * s) as f64 / (m * n) as f64).round().max(1.0) as usize
}

/// Registry solver for SaGroW (`"sagrow"`). `s_prime == 0` applies the
/// paper's budget-matching rule at solve time: `s′ = s²/(mn)` with
/// `s = sample_size` (0 → 16·max(m,n)), so SaGroW touches the same number
/// of tensor entries as Spar-GW would on the same problem.
pub struct SagrowSolver {
    /// Ground cost `L`.
    pub cost: GroundCost,
    /// SaGroW parameters (`s_prime == 0` → budget-matched per problem).
    pub cfg: SagrowConfig,
    /// Spar-GW-equivalent sample budget used by the matching rule.
    pub sample_size: usize,
}

impl SagrowSolver {
    pub(crate) fn from_opts(base: &SolverBase, o: &mut Opts) -> Result<Self> {
        o.precision_f64_only("sagrow", base.precision)?;
        Ok(SagrowSolver {
            cost: o.cost(base.cost)?,
            cfg: SagrowConfig {
                epsilon: o.f64("epsilon", base.epsilon)?,
                s_prime: o.usize("s_prime", 0)?,
                outer_iters: o.usize("outer", base.outer_iters)?,
                inner_iters: o.usize("inner", base.inner_iters)?,
                reg: o.reg(base.reg)?,
                tol: o.f64("tol", base.tol)?,
            },
            sample_size: o.usize("s", base.sample_size)?,
        })
    }

    /// Resolve `s_prime == 0` to the budget-matched value for an m×n
    /// problem.
    fn cfg_for(&self, m: usize, n: usize) -> SagrowConfig {
        let mut cfg = self.cfg;
        if cfg.s_prime == 0 {
            let s = if self.sample_size == 0 { 16 * m.max(n) } else { self.sample_size };
            cfg.s_prime = matched_s_prime(s, m, n);
        }
        cfg
    }

    fn report(&self, r: DenseGwResult, solve_seconds: f64) -> SolveReport {
        SolveReport {
            solver: self.name(),
            value: r.value,
            plan: Plan::Dense(r.plan),
            outer_iters: r.outer_iters,
            converged: r.converged,
            timings: PhaseTimings::basic(0.0, solve_seconds),
        }
    }
}

impl GwSolver for SagrowSolver {
    fn name(&self) -> &'static str {
        "sagrow"
    }

    fn solve(&self, p: &GwProblem, rng: &mut Rng, _ws: &mut Workspace) -> Result<SolveReport> {
        let t0 = Instant::now();
        let r = sagrow(p, self.cost, &self.cfg_for(p.m(), p.n()), rng);
        Ok(self.report(r, t0.elapsed().as_secs_f64()))
    }

    fn supports_fused(&self) -> bool {
        true
    }

    fn solve_fused(
        &self,
        p: &FgwProblem,
        rng: &mut Rng,
        _ws: &mut Workspace,
    ) -> Result<SolveReport> {
        let t0 = Instant::now();
        let r = sagrow_fgw(p, self.cost, &self.cfg_for(p.gw.m(), p.gw.n()), rng);
        Ok(self.report(r, t0.elapsed().as_secs_f64()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gw::alg1::{pga_gw, Alg1Config};
    use crate::rng::Xoshiro256;
    use crate::util::uniform;

    fn relation(n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let pts: Vec<[f64; 2]> = (0..n).map(|_| [rng.f64(), rng.f64()]).collect();
        Mat::from_fn(n, n, |i, j| crate::linalg::sqdist(&pts[i], &pts[j]).sqrt())
    }

    #[test]
    fn matched_budget_formula() {
        // s = 16n on an n×n problem: s′ = 256.
        assert_eq!(matched_s_prime(16 * 50, 50, 50), 256);
        assert_eq!(matched_s_prime(10, 100, 100), 1);
    }

    #[test]
    fn identical_spaces_near_zero() {
        let n = 12;
        let c = relation(n, 1);
        let a = uniform(n);
        let p = GwProblem::new(&c, &c, &a, &a);
        let mut rng = Xoshiro256::new(2);
        let cfg = SagrowConfig { s_prime: 64, outer_iters: 30, ..Default::default() };
        let r = sagrow(&p, GroundCost::L2, &cfg, &mut rng);
        // Stochastic gradients leave residual noise around the optimum.
        assert!(r.value < 0.1, "value {}", r.value);
    }

    #[test]
    fn approximates_pga_gw() {
        let n = 16;
        let c1 = relation(n, 3);
        let c2 = relation(n, 4);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let bench = pga_gw(
            &p,
            GroundCost::L2,
            &Alg1Config { epsilon: 0.01, outer_iters: 30, inner_iters: 60, tol: 1e-10 },
        );
        let mut rng = Xoshiro256::new(5);
        let cfg = SagrowConfig {
            epsilon: 0.01,
            s_prime: 256,
            outer_iters: 30,
            inner_iters: 60,
            ..Default::default()
        };
        let mut vals = Vec::new();
        for _ in 0..4 {
            vals.push(sagrow(&p, GroundCost::L2, &cfg, &mut rng).value);
        }
        let est = crate::util::mean(&vals);
        let rel = (est - bench.value).abs() / bench.value.max(1e-9);
        assert!(rel < 0.5, "sagrow {est} vs pga {} (rel {rel})", bench.value);
    }

    #[test]
    fn l1_cost_supported() {
        let n = 10;
        let c1 = relation(n, 6);
        let c2 = relation(n, 7);
        let a = uniform(n);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let mut rng = Xoshiro256::new(8);
        let cfg = SagrowConfig { s_prime: 32, ..Default::default() };
        let r = sagrow(&p, GroundCost::L1, &cfg, &mut rng);
        assert!(r.value.is_finite() && r.value >= -1e-9);
    }
}
