//! **Algorithm 4 — Spar-FGW**: importance sparsification for the fused GW
//! distance (Appendix A of the paper).
//!
//! Identical to Algorithm 2 except the sparse cost gains the feature term:
//! `C̃_fu(T̃) = α Σ_S L̃ T̃ + (1−α) M̃` with `M̃` the feature distances at the
//! sampled positions, and the output adds `(1−α) Σ_S M_ij T̃_ij`.
//!
//! Since the SparCore refactor this file is a thin adapter over
//! [`super::core`] with the [`Fused`] marginal strategy; outputs are
//! bit-identical to the historical standalone implementation.

use super::core::{Engine, Fused, Workspace};
use super::cost::GroundCost;
use super::fgw::FgwProblem;
use super::sampling::{GwSampler, SampledSet};
use super::solver::{GwSolver, Opts, PreparedStructure, SolveReport, SolverBase};
use super::spar_gw::{SparGwConfig, SparGwResult, SparGwSolver};
use super::tensor::SparseCostContext;
use crate::rng::Rng;
use crate::util::error::Result;

/// Run Algorithm 4 on a fused GW problem.
pub fn spar_fgw(
    p: &FgwProblem,
    cost: GroundCost,
    cfg: &SparGwConfig,
    rng: &mut Rng,
) -> SparGwResult {
    let s_budget = if cfg.sample_size == 0 {
        16 * p.gw.m().max(p.gw.n())
    } else {
        cfg.sample_size
    };
    let sampler = GwSampler::new(p.gw.a, p.gw.b, cfg.shrink);
    let set = sampler.sample_iid(rng, s_budget);
    spar_fgw_with_set(p, cost, cfg, &set)
}

/// Algorithm 4 with an externally supplied index set. Allocates a fresh
/// [`Workspace`]; batch callers should use [`spar_fgw_with_workspace`].
pub fn spar_fgw_with_set(
    p: &FgwProblem,
    cost: GroundCost,
    cfg: &SparGwConfig,
    set: &SampledSet,
) -> SparGwResult {
    let mut ws = Workspace::new();
    spar_fgw_with_workspace(p, cost, cfg, set, &mut ws)
}

/// Algorithm 4 on the shared [`SparCore` engine](super::core): the
/// [`Engine`] outer loop with the [`Fused`] marginal strategy (the fused
/// cost `α·C̃ + (1−α)·M̃` and the `α·ĜW + (1−α)·⟨M̃,T̃⟩` objective).
pub fn spar_fgw_with_workspace(
    p: &FgwProblem,
    cost: GroundCost,
    cfg: &SparGwConfig,
    set: &SampledSet,
    ws: &mut Workspace,
) -> SparGwResult {
    let ctx = SparseCostContext::new(p.gw.cx, p.gw.cy, &set.rows, &set.cols, cost);
    // M̃: feature distances at the sampled positions.
    let feat_vals: Vec<f64> = set
        .rows
        .iter()
        .zip(&set.cols)
        .map(|(&i, &j)| p.feat[(i, j)])
        .collect();
    let eng = Engine {
        a: p.gw.a,
        b: p.gw.b,
        a64: p.gw.a,
        b64: p.gw.b,
        set,
        ctx: &ctx,
        outer_iters: cfg.outer_iters,
        tol: cfg.tol,
    };
    let mut strategy = Fused {
        epsilon: cfg.epsilon,
        reg: cfg.reg,
        inner_iters: cfg.inner_iters,
        alpha: p.alpha,
        feat_vals: &feat_vals,
    };
    eng.solve(&mut strategy, ws)
}

/// [`spar_fgw_with_workspace`] in mixed precision: the fused cost, kernel
/// and inner Sinkhorn run in f32 on the workspace's
/// [`lane32`](Workspace::lane32); the final objective and plan stay f64.
pub fn spar_fgw_with_workspace_f32(
    p: &FgwProblem,
    cost: GroundCost,
    cfg: &SparGwConfig,
    set: &SampledSet,
    ws: &mut Workspace,
) -> SparGwResult {
    let ctx = SparseCostContext::new(p.gw.cx, p.gw.cy, &set.rows, &set.cols, cost);
    let feat_vals: Vec<f32> = set
        .rows
        .iter()
        .zip(&set.cols)
        .map(|(&i, &j)| p.feat[(i, j)] as f32)
        .collect();
    let a32: Vec<f32> = p.gw.a.iter().map(|&x| x as f32).collect();
    let b32: Vec<f32> = p.gw.b.iter().map(|&x| x as f32).collect();
    let eng = Engine {
        a: &a32,
        b: &b32,
        a64: p.gw.a,
        b64: p.gw.b,
        set,
        ctx: &ctx,
        outer_iters: cfg.outer_iters,
        tol: cfg.tol,
    };
    let mut strategy = Fused {
        epsilon: cfg.epsilon,
        reg: cfg.reg,
        inner_iters: cfg.inner_iters,
        alpha: p.alpha,
        feat_vals: &feat_vals,
    };
    eng.solve(&mut strategy, ws.lane32())
}

/// Registry solver for Algorithm 4 (`"spar_fgw"`). On a fused problem it
/// runs the [`Fused`] strategy with the problem's α and features; on a
/// plain GW problem (no features) Algorithm 4 degenerates to Algorithm 2
/// exactly (α = 1 drops the feature term), so `solve` delegates to the
/// balanced engine. Internally a thin wrapper over [`SparGwSolver`], whose
/// config grammar it shares.
pub struct SparFgwSolver {
    inner: SparGwSolver,
}

impl SparFgwSolver {
    pub(crate) fn from_opts(base: &SolverBase, o: &mut Opts) -> Result<Self> {
        Ok(SparFgwSolver { inner: SparGwSolver::from_opts(base, o)? })
    }
}

impl GwSolver for SparFgwSolver {
    fn name(&self) -> &'static str {
        "spar_fgw"
    }

    fn solve(
        &self,
        p: &super::GwProblem,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<SolveReport> {
        let mut report = self.inner.solve(p, rng, ws)?;
        report.solver = self.name();
        Ok(report)
    }

    fn supports_fused(&self) -> bool {
        true
    }

    fn solve_fused(
        &self,
        p: &FgwProblem,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<SolveReport> {
        let mut report = self.inner.solve_fused(p, rng, ws)?;
        report.solver = self.name();
        Ok(report)
    }

    fn solve_prepared(
        &self,
        p: &super::GwProblem,
        sx: &PreparedStructure,
        sy: &PreparedStructure,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<SolveReport> {
        let mut report = self.inner.solve_prepared(p, sx, sy, rng, ws)?;
        report.solver = self.name();
        Ok(report)
    }

    fn solve_fused_prepared(
        &self,
        p: &FgwProblem,
        sx: &PreparedStructure,
        sy: &PreparedStructure,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<SolveReport> {
        let mut report = self.inner.solve_fused_prepared(p, sx, sy, rng, ws)?;
        report.solver = self.name();
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gw::alg1::Alg1Config;
    use crate::gw::fgw::pga_fgw;
    use crate::gw::spar_gw::spar_gw;
    use crate::gw::GwProblem;
    use crate::linalg::Mat;
    use crate::rng::Xoshiro256;
    use crate::util::uniform;

    fn relation(n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let pts: Vec<[f64; 2]> = (0..n).map(|_| [rng.f64(), rng.f64()]).collect();
        Mat::from_fn(n, n, |i, j| crate::linalg::sqdist(&pts[i], &pts[j]).sqrt())
    }

    #[test]
    fn alpha_one_matches_spar_gw() {
        let n = 15;
        let c1 = relation(n, 1);
        let c2 = relation(n, 2);
        let a = uniform(n);
        let feat = Mat::full(n, n, 3.0);
        let gw = GwProblem::new(&c1, &c2, &a, &a);
        let p = FgwProblem::new(gw, &feat, 1.0);
        let cfg = SparGwConfig { sample_size: 12 * n, ..Default::default() };
        // Same seed ⇒ same sampled set ⇒ identical trajectories.
        let mut rng1 = Xoshiro256::new(5);
        let mut rng2 = Xoshiro256::new(5);
        let rf = spar_fgw(&p, GroundCost::L2, &cfg, &mut rng1);
        let rg = spar_gw(&gw, GroundCost::L2, &cfg, &mut rng2);
        assert!(
            (rf.value - rg.value).abs() < 1e-10,
            "spar-fgw(α=1) {} vs spar-gw {}",
            rf.value,
            rg.value
        );
    }

    #[test]
    fn approximates_dense_fgw() {
        let n = 20;
        let c1 = relation(n, 3);
        let c2 = relation(n, 4);
        let a = uniform(n);
        let mut rngf = Xoshiro256::new(6);
        let feat = Mat::from_fn(n, n, |_, _| rngf.f64());
        let gw = GwProblem::new(&c1, &c2, &a, &a);
        let p = FgwProblem::new(gw, &feat, 0.6);
        let dense_cfg = Alg1Config { epsilon: 0.01, outer_iters: 30, inner_iters: 60, tol: 1e-10 };
        let bench = pga_fgw(&p, GroundCost::L2, &dense_cfg);

        let cfg = SparGwConfig {
            epsilon: 0.01,
            sample_size: 16 * n,
            outer_iters: 30,
            inner_iters: 60,
            ..Default::default()
        };
        let mut rng = Xoshiro256::new(7);
        let mut vals = Vec::new();
        for _ in 0..5 {
            vals.push(spar_fgw(&p, GroundCost::L2, &cfg, &mut rng).value);
        }
        let est = crate::util::mean(&vals);
        let rel = (est - bench.value).abs() / bench.value.max(1e-9);
        assert!(rel < 0.5, "spar-fgw {est} vs dense {} (rel {rel})", bench.value);
    }

    #[test]
    fn l1_cost_supported() {
        let n = 12;
        let c1 = relation(n, 8);
        let c2 = relation(n, 9);
        let a = uniform(n);
        let feat = Mat::from_fn(n, n, |i, j| ((i + j) % 3) as f64 * 0.2);
        let gw = GwProblem::new(&c1, &c2, &a, &a);
        let p = FgwProblem::new(gw, &feat, 0.6);
        let mut rng = Xoshiro256::new(10);
        let cfg = SparGwConfig { sample_size: 10 * n, ..Default::default() };
        let r = spar_fgw(&p, GroundCost::L1, &cfg, &mut rng);
        assert!(r.value.is_finite() && r.value >= -1e-9);
    }
}
