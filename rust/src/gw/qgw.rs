//! **Quantized recursive GW (`qgw`)** — the hierarchical million-point
//! tier (following Chowdhury, Miller & Needham 2021, arXiv 2104.02013).
//!
//! Three phases, none of which allocates O(n²):
//!
//! 1. **Partition** — pick m anchor points per side (m ≈ √n by default):
//!    the first anchor is a marginal-weighted draw through the crate's
//!    alias-table sampling machinery, the rest by farthest-point
//!    traversal, optionally refined by weighted k-medoid sweeps. Every
//!    atom is assigned to its nearest anchor (O(n·m) relation entries,
//!    pool-parallel, element-wise ⇒ bit-identical at any width).
//! 2. **Coarse solve** — gather the m×m anchor relation blocks, put the
//!    partition masses on them as marginals, and hand the small dense
//!    problem to a **registry-dispatched inner solver** (default
//!    `spar_gw`, so the whole SparCore/SIMD/pool stack accelerates the
//!    coarse level; any leaf solver name works via `inner=`).
//! 3. **Extension** — for each coarse coupling entry (u, v) with mass
//!    t_uv, couple the members of partition u to the members of partition
//!    v by a northwest-corner transport between their conditional
//!    marginals (members ordered by distance-to-own-anchor), scaled by
//!    t_uv. Each block contributes ≤ |P_u| + |P_v| − 1 entries, so the
//!    extended [`Plan::Sparse`] holds O(coarse-nnz · n/m) = O(n)
//!    entries, never n².
//!
//! The reported value is the coarse GW estimate (the quantized
//! approximation); `outer_iters`/`converged` are the inner solver's.
//! Relations come in through [`Relation`], so the same code serves the
//! registry's dense `GwProblem` entry point *and* the O(n)-memory
//! [`PointCloud`] path (`QgwSolver::solve_points`, used by the CLI for
//! point workloads) — with bit-identical results when the dense matrix
//! equals the materialized cloud.

use std::collections::BTreeMap;
use std::time::Instant;

use super::core::Workspace;
use super::cost::GroundCost;
use super::relation::{PointCloud, Relation};
use super::solver::{
    normalize, GwSolver, Opts, PhaseDetail, PhaseTimings, Plan, SolveReport, SolverBase,
    SolverRegistry,
};
use super::GwProblem;
use crate::ensure;
use crate::rng::{AliasTable, Rng};
use crate::runtime::pool::pool;
use crate::sparse::Coo;
use crate::util::error::Result;

/// Configuration for the quantized solver.
#[derive(Clone, Debug)]
pub struct QgwConfig {
    /// Anchor count m per side (0 → ⌈√n⌉, clamped to [1, n]).
    pub anchors: usize,
    /// Weighted k-medoid refinement sweeps after farthest-point seeding.
    pub refine_iters: usize,
    /// Registry name of the coarse-level solver (any leaf engine).
    pub inner: String,
}

impl Default for QgwConfig {
    fn default() -> Self {
        QgwConfig { anchors: 0, refine_iters: 1, inner: "spar_gw".to_string() }
    }
}

/// One side's quantization: anchors, per-partition mass, and the member
/// lists ordered by (distance to own anchor, index) — the order the
/// northwest-corner extension consumes.
struct SidePartition {
    /// Anchor atom indices (one per non-empty, positive-mass partition).
    anchors: Vec<usize>,
    /// Marginal mass per partition (coarse marginal; sums to 1).
    mass: Vec<f64>,
    /// Member atom indices per partition, sorted by (dist, index).
    members: Vec<Vec<usize>>,
}

/// Effective anchor count for an n-atom side.
fn auto_anchors(requested: usize, n: usize) -> usize {
    let m = if requested == 0 { (n as f64).sqrt().ceil() as usize } else { requested };
    m.clamp(1, n)
}

/// Nearest-anchor assignment: for every atom, the partition index of the
/// closest anchor (ties → lowest partition index) and that distance.
/// Element-wise over atoms on the worker pool — bit-identical at any
/// pool width and chunking.
fn assign_nearest(rel: &Relation, anchors: &[usize], out: &mut [(f64, u32)]) {
    pool().for_each_chunk_mut(out, 1024, |chunk, range, _| {
        for (slot, i) in chunk.iter_mut().zip(range) {
            let mut best = f64::INFINITY;
            let mut best_u = 0u32;
            for (u, &anchor) in anchors.iter().enumerate() {
                let d = rel.entry(i, anchor);
                if d < best {
                    best = d;
                    best_u = u as u32;
                }
            }
            *slot = (best, best_u);
        }
    });
}

/// Phase 1: quantize one side. The first anchor is a marginal-weighted
/// alias-table draw, the rest farthest-point picks (ties → lowest index),
/// optionally refined by weighted k-medoid sweeps. Partitions that end up
/// empty or with zero marginal mass are dropped (they carry no coupling
/// mass and would otherwise produce 0/0 conditionals).
fn quantize(
    rel: &Relation,
    marginal: &[f64],
    m: usize,
    refine: usize,
    rng: &mut Rng,
) -> SidePartition {
    let n = rel.len();
    let m = auto_anchors(m, n);
    let mut anchors = Vec::with_capacity(m);
    anchors.push(AliasTable::new(marginal).sample(rng));

    // Farthest-point traversal: keep each atom's distance to the nearest
    // chosen anchor, extend with the argmax (pool-parallel min-update,
    // serial argmax scan — both deterministic).
    let mut nearest = vec![0.0f64; n];
    rel.column_into(anchors[0], &mut nearest);
    while anchors.len() < m {
        let last = *anchors.last().unwrap();
        if anchors.len() > 1 {
            let relc = *rel;
            pool().for_each_chunk_mut(&mut nearest, 1024, |chunk, range, _| {
                for (slot, i) in chunk.iter_mut().zip(range) {
                    let d = relc.entry(i, last);
                    if d < *slot {
                        *slot = d;
                    }
                }
            });
        }
        let mut far = 0usize;
        for i in 1..n {
            if nearest[i] > nearest[far] {
                far = i;
            }
        }
        anchors.push(far);
    }

    // Nearest-anchor assignment (+ optional k-medoid refinement: each
    // partition's anchor moves to its weighted medoid, then re-assign).
    let mut near: Vec<(f64, u32)> = vec![(0.0, 0); n];
    assign_nearest(rel, &anchors, &mut near);
    for _ in 0..refine {
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); anchors.len()];
        for (i, &(_, u)) in near.iter().enumerate() {
            members[u as usize].push(i);
        }
        let relc = *rel;
        let membs = &members;
        let marg = marginal;
        let old = anchors.clone();
        pool().for_each_chunk_mut(&mut anchors, 1, |chunk, range, _| {
            for (slot, u) in chunk.iter_mut().zip(range) {
                let pu = &membs[u];
                if pu.is_empty() {
                    *slot = old[u];
                    continue;
                }
                let mut best = f64::INFINITY;
                let mut best_p = pu[0];
                for &p in pu {
                    let mut s = 0.0;
                    for &q in pu {
                        s += marg[q] * relc.entry(p, q);
                    }
                    if s < best {
                        best = s;
                        best_p = p;
                    }
                }
                *slot = best_p;
            }
        });
        assign_nearest(rel, &anchors, &mut near);
    }

    // Final grouping: members sorted by (distance to own anchor, index),
    // mass summed in that order; drop empty/zero-mass partitions.
    let mut grouped: Vec<Vec<usize>> = vec![Vec::new(); anchors.len()];
    for (i, &(_, u)) in near.iter().enumerate() {
        grouped[u as usize].push(i);
    }
    let mut kept_anchors = Vec::new();
    let mut kept_mass = Vec::new();
    let mut kept_members = Vec::new();
    for (u, mut pu) in grouped.into_iter().enumerate() {
        pu.sort_by(|&p, &q| {
            near[p].0.partial_cmp(&near[q].0).unwrap().then(p.cmp(&q))
        });
        let mass: f64 = pu.iter().map(|&p| marginal[p]).sum();
        if !pu.is_empty() && mass > 0.0 {
            kept_anchors.push(anchors[u]);
            kept_mass.push(mass);
            kept_members.push(pu);
        }
    }
    SidePartition { anchors: kept_anchors, mass: kept_mass, members: kept_members }
}

/// Phase 3: extend one coarse entry (u, v, t) by a northwest-corner
/// transport between the member conditionals, scaled by t. Appends
/// ≤ |P_u| + |P_v| − 1 triplets.
#[allow(clippy::too_many_arguments)]
fn extend_block(
    px: &SidePartition,
    py: &SidePartition,
    a: &[f64],
    b: &[f64],
    u: usize,
    v: usize,
    t: f64,
    rows: &mut Vec<usize>,
    cols: &mut Vec<usize>,
    vals: &mut Vec<f64>,
) {
    if t <= 0.0 {
        return;
    }
    let pu = &px.members[u];
    let pv = &py.members[v];
    let (au, bv) = (px.mass[u], py.mass[v]);
    let (mut i, mut j) = (0usize, 0usize);
    let mut ra = a[pu[0]] / au * t;
    let mut rb = b[pv[0]] / bv * t;
    while i < pu.len() && j < pv.len() {
        let m = ra.min(rb);
        if m > 0.0 {
            rows.push(pu[i]);
            cols.push(pv[j]);
            vals.push(m);
        }
        if ra <= rb {
            rb -= ra;
            i += 1;
            if i < pu.len() {
                ra = a[pu[i]] / au * t;
            }
        } else {
            ra -= rb;
            j += 1;
            if j < pv.len() {
                rb = b[pv[j]] / bv * t;
            }
        }
    }
}

/// Registry solver for quantized recursive GW (`"qgw"`). Holds the
/// registry-built inner solver for the coarse level; options: `anchors=`
/// (0 → ⌈√n⌉), `refine=` (k-medoid sweeps), `inner=` (coarse solver
/// name), plus the usual `cost=`/`epsilon=`/`s=`/`outer=`/`reg=`/
/// `shrink=`/`tol=`/`precision=` forwarded into the inner solve.
pub struct QgwSolver {
    /// Quantization parameters.
    pub cfg: QgwConfig,
    /// The coarse-level engine (built once, registry-dispatched).
    inner: Box<dyn GwSolver>,
}

impl QgwSolver {
    pub(crate) fn from_opts(base: &SolverBase, o: &mut Opts) -> Result<Self> {
        let d = QgwConfig::default();
        let cfg = QgwConfig {
            anchors: o.usize("anchors", d.anchors)?,
            refine_iters: o.usize("refine", d.refine_iters)?,
            inner: o.string("inner", &d.inner)?,
        };
        ensure!(
            normalize(&cfg.inner) != "qgw",
            "solver \"qgw\": inner solver must be a leaf engine, got {:?} \
             (the recursion bottoms out at the coarse level)",
            cfg.inner
        );
        let inner_base = SolverBase {
            cost: o.cost(base.cost)?,
            epsilon: o.f64("epsilon", base.epsilon)?,
            sample_size: o.usize("s", base.sample_size)?,
            outer_iters: o.usize("outer", base.outer_iters)?,
            reg: o.reg(base.reg)?,
            shrink: o.f64("shrink", base.shrink)?,
            tol: o.f64("tol", base.tol)?,
            precision: o.precision(base.precision)?,
            ..*base
        };
        let inner = SolverRegistry::build_with_base(&cfg.inner, &BTreeMap::new(), &inner_base)?;
        Ok(QgwSolver { cfg, inner })
    }

    /// Registry name of the coarse-level engine.
    pub fn inner_name(&self) -> &'static str {
        self.inner.name()
    }

    /// The million-point entry: implicit Euclidean relations over point
    /// clouds — O(n·dim + n·m + coarse + nnz) memory, no n×n matrix
    /// anywhere. Bit-identical to [`GwSolver::solve`] on the materialized
    /// distance matrices of the same clouds.
    pub fn solve_points(
        &self,
        px: &PointCloud,
        py: &PointCloud,
        a: &[f64],
        b: &[f64],
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<SolveReport> {
        assert_eq!(px.len(), a.len(), "qgw: source cloud/marginal mismatch");
        assert_eq!(py.len(), b.len(), "qgw: target cloud/marginal mismatch");
        self.solve_relations(Relation::Points(px), Relation::Points(py), a, b, rng, ws)
    }

    /// The shared three-phase pipeline over any relation representation.
    fn solve_relations(
        &self,
        rx: Relation,
        ry: Relation,
        a: &[f64],
        b: &[f64],
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<SolveReport> {
        // Phase 1: partition both sides.
        let t0 = Instant::now();
        let px = quantize(&rx, a, self.cfg.anchors, self.cfg.refine_iters, rng);
        let py = quantize(&ry, b, self.cfg.anchors, self.cfg.refine_iters, rng);
        let partition_seconds = t0.elapsed().as_secs_f64();

        // Phase 2: coarse solve on the gathered anchor blocks.
        let t1 = Instant::now();
        let cax = rx.gather(&px.anchors, &px.anchors);
        let cay = ry.gather(&py.anchors, &py.anchors);
        let coarse_p = GwProblem::new(&cax, &cay, &px.mass, &py.mass);
        let coarse = self.inner.solve(&coarse_p, rng, ws)?;
        let coarse_seconds = t1.elapsed().as_secs_f64();

        // Phase 3: northwest-corner extension within matched partitions,
        // walking the coarse plan in its deterministic storage order.
        let t2 = Instant::now();
        let mut rows = Vec::new();
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        let mut emit = |u: usize, v: usize, t: f64| {
            extend_block(&px, &py, a, b, u, v, t, &mut rows, &mut cols, &mut vals)
        };
        match &coarse.plan {
            Plan::Dense(t) => {
                for u in 0..t.rows() {
                    let row = t.row(u);
                    for (v, &tv) in row.iter().enumerate() {
                        emit(u, v, tv);
                    }
                }
            }
            Plan::Sparse(t) => {
                for ((&u, &v), &tv) in t.rows().iter().zip(t.cols()).zip(t.vals()) {
                    emit(u as usize, v as usize, tv);
                }
            }
            Plan::Factored(t) => {
                let dense = t.reconstruct();
                for u in 0..dense.rows() {
                    let row = dense.row(u);
                    for (v, &tv) in row.iter().enumerate() {
                        emit(u, v, tv);
                    }
                }
            }
        }
        let plan = Coo::from_triplets(a.len(), b.len(), &rows, &cols, &vals);
        let extension_seconds = t2.elapsed().as_secs_f64();

        Ok(SolveReport {
            solver: "qgw",
            value: coarse.value,
            plan: Plan::Sparse(plan),
            outer_iters: coarse.outer_iters,
            converged: coarse.converged,
            timings: PhaseTimings {
                sample_seconds: partition_seconds,
                solve_seconds: coarse_seconds + extension_seconds,
                detail: PhaseDetail::Quantized {
                    partition_seconds,
                    coarse_seconds,
                    extension_seconds,
                },
            },
        })
    }
}

/// Build a [`QgwSolver`] from the CLI-style option map (public so the
/// binary's point-cloud path can construct one without the `dyn GwSolver`
/// indirection). Unknown keys error like the registry build.
pub fn build(opts: &BTreeMap<String, String>, base: &SolverBase) -> Result<QgwSolver> {
    let mut o = Opts::new(opts);
    let solver = QgwSolver::from_opts(base, &mut o)?;
    o.finish("qgw")?;
    Ok(solver)
}

impl GwSolver for QgwSolver {
    fn name(&self) -> &'static str {
        "qgw"
    }

    fn solve(&self, p: &GwProblem, rng: &mut Rng, ws: &mut Workspace) -> Result<SolveReport> {
        self.solve_relations(Relation::Dense(p.cx), Relation::Dense(p.cy), p.a, p.b, rng, ws)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::pairwise_euclidean;
    use crate::rng::Xoshiro256;
    use crate::util::uniform;

    fn random_points(n: usize, dim: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Xoshiro256::new(seed);
        (0..n).map(|_| (0..dim).map(|_| rng.f64()).collect()).collect()
    }

    fn build_default() -> QgwSolver {
        build(&BTreeMap::new(), &SolverBase::default()).unwrap()
    }

    #[test]
    fn quantize_covers_every_atom_once() {
        let pts = random_points(40, 2, 1);
        let cloud = PointCloud::from_points(&pts);
        let a = uniform(40);
        let mut rng = Xoshiro256::new(3);
        let part = quantize(&Relation::Points(&cloud), &a, 0, 1, &mut rng);
        assert_eq!(part.anchors.len(), part.members.len());
        assert_eq!(part.anchors.len(), part.mass.len());
        let mut seen = vec![false; 40];
        for pu in &part.members {
            for &p in pu {
                assert!(!seen[p], "atom {p} in two partitions");
                seen[p] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every atom must be assigned");
        let total: f64 = part.mass.iter().sum();
        assert!((total - 1.0).abs() < 1e-12, "coarse mass {total}");
    }

    #[test]
    fn extended_plan_mass_matches_coarse_mass() {
        let xs = random_points(48, 2, 5);
        let ys = random_points(48, 3, 6);
        let cx = pairwise_euclidean(&xs);
        let cy = pairwise_euclidean(&ys);
        let a = uniform(48);
        let p = GwProblem::new(&cx, &cy, &a, &a);
        let solver = build_default();
        let mut rng = Xoshiro256::new(9);
        let mut ws = Workspace::new();
        let r = solver.solve(&p, &mut rng, &mut ws).unwrap();
        assert!(r.value.is_finite() && r.value >= -1e-9, "value {}", r.value);
        assert!(r.plan.is_finite());
        assert!((r.plan.sum() - 1.0).abs() < 0.1, "mass {}", r.plan.sum());
        // Sub-dense support: the whole point of the tier.
        assert!(r.plan.nnz() < 48 * 48 / 2, "nnz {}", r.plan.nnz());
        // Per-phase timings are populated.
        match r.timings.detail {
            PhaseDetail::Quantized { .. } => {}
            _ => panic!("qgw must report quantized phase detail"),
        }
    }

    #[test]
    fn points_path_is_bit_identical_to_dense_path() {
        let xs = random_points(36, 2, 11);
        let ys = random_points(36, 2, 12);
        let cx = pairwise_euclidean(&xs);
        let cy = pairwise_euclidean(&ys);
        let pcx = PointCloud::from_points(&xs);
        let pcy = PointCloud::from_points(&ys);
        let a = uniform(36);

        let solver = build_default();
        let p = GwProblem::new(&cx, &cy, &a, &a);
        let mut rng1 = Xoshiro256::new(21);
        let mut ws1 = Workspace::new();
        let dense = solver.solve(&p, &mut rng1, &mut ws1).unwrap();
        let mut rng2 = Xoshiro256::new(21);
        let mut ws2 = Workspace::new();
        let pts = solver.solve_points(&pcx, &pcy, &a, &a, &mut rng2, &mut ws2).unwrap();

        assert_eq!(dense.value.to_bits(), pts.value.to_bits());
        assert_eq!(dense.outer_iters, pts.outer_iters);
        assert_eq!(dense.plan.nnz(), pts.plan.nnz());
        assert_eq!(dense.plan.sum().to_bits(), pts.plan.sum().to_bits());
        let (rd, rp) = (dense.plan.row_sums(), pts.plan.row_sums());
        for i in 0..36 {
            assert_eq!(rd[i].to_bits(), rp[i].to_bits(), "row {i}");
        }
    }

    #[test]
    fn inner_solver_is_dispatchable() {
        let mut opts = BTreeMap::new();
        opts.insert("inner".to_string(), "egw".to_string());
        let solver = build(&opts, &SolverBase::default()).unwrap();
        assert_eq!(solver.inner_name(), "egw");
        let xs = random_points(20, 2, 31);
        let cx = pairwise_euclidean(&xs);
        let a = uniform(20);
        let p = GwProblem::new(&cx, &cx, &a, &a);
        let mut rng = Xoshiro256::new(5);
        let mut ws = Workspace::new();
        let r = solver.solve(&p, &mut rng, &mut ws).unwrap();
        assert_eq!(r.solver, "qgw");
        assert!(r.value.is_finite());
    }

    #[test]
    fn recursive_inner_is_rejected() {
        let mut opts = BTreeMap::new();
        opts.insert("inner".to_string(), "qgw".to_string());
        let err = build(&opts, &SolverBase::default()).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("leaf"), "{msg}");
    }

    #[test]
    fn unknown_inner_name_errors_descriptively() {
        let mut opts = BTreeMap::new();
        opts.insert("inner".to_string(), "warp_drive".to_string());
        let err = build(&opts, &SolverBase::default()).unwrap_err();
        assert!(format!("{err}").contains("unknown solver"), "{err}");
    }

    #[test]
    fn marginals_track_inputs_within_coarse_error() {
        // The extension distributes each partition's coarse marginal over
        // its members proportionally to the input marginal, so the L1
        // marginal error of the extended plan equals the coarse solver's.
        let xs = random_points(50, 2, 41);
        let ys = random_points(50, 2, 42);
        let cx = pairwise_euclidean(&xs);
        let cy = pairwise_euclidean(&ys);
        let mut rng0 = Xoshiro256::new(43);
        let mut a: Vec<f64> = (0..50).map(|_| rng0.f64() + 0.1).collect();
        crate::util::normalize(&mut a);
        let b = uniform(50);
        let p = GwProblem::new(&cx, &cy, &a, &b);
        let solver = build_default();
        let mut rng = Xoshiro256::new(44);
        let mut ws = Workspace::new();
        let r = solver.solve(&p, &mut rng, &mut ws).unwrap();
        let rows = r.plan.row_sums();
        let err: f64 = rows.iter().zip(&a).map(|(x, y)| (x - y).abs()).sum();
        assert!(err < 0.5, "L1 row-marginal error {err}");
    }
}
