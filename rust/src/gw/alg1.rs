//! Algorithm 1 — the dense iterative scheme for (entropic / proximal) GW,
//! plus the EMD-GW baseline (ε = 0 with an exact inner OT solver).

use std::time::Instant;

use super::core::Workspace;
use super::cost::GroundCost;
use super::fgw::{egw_fgw, emd_fgw, pga_fgw, FgwProblem};
use super::solver::{GwSolver, Opts, PhaseTimings, Plan, SolveReport, SolverBase};
use super::tensor::tensor_product;
use super::{DenseGwResult, GwProblem, Regularizer};
use crate::linalg::Mat;
use crate::ot::{emd, sinkhorn};
use crate::rng::Rng;
use crate::util::error::Result;

/// Configuration for the dense Algorithm-1 solvers.
#[derive(Clone, Copy, Debug)]
pub struct Alg1Config {
    /// Regularization weight ε of subproblem (4).
    pub epsilon: f64,
    /// Outer iterations R.
    pub outer_iters: usize,
    /// Inner Sinkhorn iterations H.
    pub inner_iters: usize,
    /// Outer stopping tolerance on ‖T⁽ʳ⁺¹⁾ − T⁽ʳ⁾‖_F (0 disables).
    pub tol: f64,
}

impl Default for Alg1Config {
    fn default() -> Self {
        Alg1Config { epsilon: 0.01, outer_iters: 20, inner_iters: 50, tol: 1e-9 }
    }
}

/// Build the Sinkhorn kernel `exp(−C/ε)` (optionally ⊙ T for the proximal
/// variant) with a row/column min reduction first: balanced Sinkhorn
/// projections are invariant to `C_ij ← C_ij − r_i − c_j` (the shifts are
/// absorbed by the scaling vectors), and the reduction keeps the exponent
/// small so the kernel does not underflow when the cost scale ≫ ε.
pub(crate) fn stabilized_kernel(c: &Mat, t: Option<&Mat>, eps: f64) -> Mat {
    let (m, n) = c.shape();
    // Row mins.
    let row_min: Vec<f64> = (0..m)
        .map(|i| c.row(i).iter().cloned().fold(f64::INFINITY, f64::min))
        .collect();
    // Column mins of the row-reduced matrix.
    let mut col_min = vec![f64::INFINITY; n];
    for i in 0..m {
        let crow = c.row(i);
        for j in 0..n {
            let v = crow[j] - row_min[i];
            if v < col_min[j] {
                col_min[j] = v;
            }
        }
    }
    let mut k = Mat::zeros(m, n);
    for i in 0..m {
        let crow = c.row(i);
        let krow = k.row_mut(i);
        for j in 0..n {
            let e = (-(crow[j] - row_min[i] - col_min[j]) / eps).exp();
            krow[j] = match t {
                Some(t) => e * t[(i, j)],
                None => e,
            };
        }
    }
    k
}

/// One shared implementation of Algorithm 1 for both regularizers.
fn alg1(p: &GwProblem, cost: GroundCost, reg: Regularizer, cfg: &Alg1Config) -> DenseGwResult {
    let mut t = Mat::outer(p.a, p.b); // T⁽⁰⁾ = a bᵀ
    let mut converged = false;
    let mut outer = 0;
    for _r in 0..cfg.outer_iters {
        // Step 4a: cost matrix C(T⁽ʳ⁾).
        let c = tensor_product(p.cx, p.cy, &t, cost);
        // Step 4b: kernel matrix (stabilized; see `stabilized_kernel`).
        let k = match reg {
            Regularizer::Proximal => stabilized_kernel(&c, Some(&t), cfg.epsilon),
            Regularizer::Entropy => stabilized_kernel(&c, None, cfg.epsilon),
        };
        // Step 5: Sinkhorn projection.
        let res = sinkhorn(p.a, p.b, &k, cfg.inner_iters, 0.0);
        let t_next = res.plan;
        outer += 1;
        if cfg.tol > 0.0 {
            let mut diff = 0.0;
            for (x, y) in t_next.data().iter().zip(t.data()) {
                let d = x - y;
                diff += d * d;
            }
            if diff.sqrt() < cfg.tol {
                t = t_next;
                converged = true;
                break;
            }
        }
        t = t_next;
    }
    // Output: GW = ⟨C(T⁽ᴿ⁾), T⁽ᴿ⁾⟩.
    let c_final = tensor_product(p.cx, p.cy, &t, cost);
    let value = c_final.frob_inner(&t);
    DenseGwResult { value, plan: t, outer_iters: outer, converged }
}

/// Entropic GW (Peyré et al. 2016): Algorithm 1 with `R(T) = H(T)`.
pub fn egw(p: &GwProblem, cost: GroundCost, cfg: &Alg1Config) -> DenseGwResult {
    alg1(p, cost, Regularizer::Entropy, cfg)
}

/// Proximal-gradient GW (Xu et al. 2019b): `R(T) = KL(T ‖ T⁽ʳ⁾)`.
/// This is the paper's accuracy benchmark in Figures 2/5/6.
pub fn pga_gw(p: &GwProblem, cost: GroundCost, cfg: &Alg1Config) -> DenseGwResult {
    alg1(p, cost, Regularizer::Proximal, cfg)
}

/// EMD-GW: ε = 0 — each subproblem is the unregularized LP
/// `min ⟨C(T⁽ʳ⁾), T⟩` solved exactly by the transportation simplex
/// (conditional gradient with unit step, per §6.1(iii)).
pub fn emd_gw(p: &GwProblem, cost: GroundCost, cfg: &Alg1Config) -> DenseGwResult {
    let mut t = Mat::outer(p.a, p.b);
    let mut converged = false;
    let mut outer = 0;
    for _r in 0..cfg.outer_iters {
        let c = tensor_product(p.cx, p.cy, &t, cost);
        let res = emd(p.a, p.b, &c);
        let t_next = res.plan;
        outer += 1;
        if cfg.tol > 0.0 {
            let mut diff = 0.0;
            for (x, y) in t_next.data().iter().zip(t.data()) {
                let d = x - y;
                diff += d * d;
            }
            if diff.sqrt() < cfg.tol {
                t = t_next;
                converged = true;
                break;
            }
        }
        t = t_next;
    }
    let c_final = tensor_product(p.cx, p.cy, &t, cost);
    let value = c_final.frob_inner(&t);
    DenseGwResult { value, plan: t, outer_iters: outer, converged }
}

/// Which Algorithm-1 variant an [`Alg1Solver`] runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Alg1Kind {
    /// Entropic GW (`"egw"`).
    Egw,
    /// Proximal-gradient GW (`"pga_gw"`) — the accuracy benchmark.
    PgaGw,
    /// ε = 0 with an exact inner OT solver (`"emd_gw"`).
    EmdGw,
}

/// Registry solver for the dense Algorithm-1 family. Deterministic (the
/// RNG is untouched) and dense (the workspace is untouched); extends to
/// the fused objective through the `fgw` variants.
pub struct Alg1Solver {
    /// Which variant to run.
    pub kind: Alg1Kind,
    /// Ground cost `L`.
    pub cost: GroundCost,
    /// Algorithm-1 parameters.
    pub cfg: Alg1Config,
}

impl Alg1Solver {
    pub(crate) fn from_opts(kind: Alg1Kind, base: &SolverBase, o: &mut Opts) -> Result<Self> {
        let name = match kind {
            Alg1Kind::Egw => "egw",
            Alg1Kind::PgaGw => "pga_gw",
            Alg1Kind::EmdGw => "emd_gw",
        };
        o.precision_f64_only(name, base.precision)?;
        Ok(Alg1Solver {
            kind,
            cost: o.cost(base.cost)?,
            cfg: Alg1Config {
                epsilon: o.f64("epsilon", base.epsilon)?,
                outer_iters: o.usize("outer", base.outer_iters)?,
                inner_iters: o.usize("inner", base.inner_iters)?,
                tol: o.f64("tol", base.tol)?,
            },
        })
    }

    fn report(&self, r: DenseGwResult, solve_seconds: f64) -> SolveReport {
        SolveReport {
            solver: self.name(),
            value: r.value,
            plan: Plan::Dense(r.plan),
            outer_iters: r.outer_iters,
            converged: r.converged,
            timings: PhaseTimings::basic(0.0, solve_seconds),
        }
    }
}

impl GwSolver for Alg1Solver {
    fn name(&self) -> &'static str {
        match self.kind {
            Alg1Kind::Egw => "egw",
            Alg1Kind::PgaGw => "pga_gw",
            Alg1Kind::EmdGw => "emd_gw",
        }
    }

    fn solve(&self, p: &GwProblem, _rng: &mut Rng, _ws: &mut Workspace) -> Result<SolveReport> {
        let t0 = Instant::now();
        let r = match self.kind {
            Alg1Kind::Egw => egw(p, self.cost, &self.cfg),
            Alg1Kind::PgaGw => pga_gw(p, self.cost, &self.cfg),
            Alg1Kind::EmdGw => emd_gw(p, self.cost, &self.cfg),
        };
        Ok(self.report(r, t0.elapsed().as_secs_f64()))
    }

    fn supports_fused(&self) -> bool {
        true
    }

    fn solve_fused(
        &self,
        p: &FgwProblem,
        _rng: &mut Rng,
        _ws: &mut Workspace,
    ) -> Result<SolveReport> {
        let t0 = Instant::now();
        let r = match self.kind {
            Alg1Kind::Egw => egw_fgw(p, self.cost, &self.cfg),
            Alg1Kind::PgaGw => pga_fgw(p, self.cost, &self.cfg),
            Alg1Kind::EmdGw => emd_fgw(p, self.cost, &self.cfg),
        };
        Ok(self.report(r, t0.elapsed().as_secs_f64()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::util::uniform;

    /// Euclidean distance matrix of random 2-D points.
    fn point_cloud_relation(n: usize, seed: u64, shift: f64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        let pts: Vec<[f64; 2]> = (0..n)
            .map(|_| [rng.f64() + shift, rng.f64() * 2.0])
            .collect();
        Mat::from_fn(n, n, |i, j| {
            let dx = pts[i][0] - pts[j][0];
            let dy = pts[i][1] - pts[j][1];
            (dx * dx + dy * dy).sqrt()
        })
    }

    #[test]
    fn identical_spaces_give_zero() {
        let n = 8;
        let c = point_cloud_relation(n, 42, 0.0);
        let a = uniform(n);
        let p = GwProblem::new(&c, &c, &a, &a);
        let cfg = Alg1Config { epsilon: 0.005, outer_iters: 50, inner_iters: 100, tol: 1e-10 };
        for cost in [GroundCost::L1, GroundCost::L2] {
            let r = pga_gw(&p, cost, &cfg);
            assert!(r.value < 5e-3, "{cost:?}: GW = {}", r.value);
        }
    }

    #[test]
    fn invariant_to_permutation() {
        // GW between a space and a permuted copy is ~0.
        let n = 7;
        let c = point_cloud_relation(n, 3, 0.0);
        let perm: Vec<usize> = vec![3, 1, 4, 0, 6, 2, 5];
        let cp = Mat::from_fn(n, n, |i, j| c[(perm[i], perm[j])]);
        let a = uniform(n);
        let p = GwProblem::new(&c, &cp, &a, &a);
        let cfg = Alg1Config { epsilon: 0.005, outer_iters: 60, inner_iters: 100, tol: 1e-10 };
        let r = pga_gw(&p, GroundCost::L2, &cfg);
        assert!(r.value < 5e-3, "GW = {}", r.value);
    }

    #[test]
    fn distinct_spaces_give_positive() {
        let c1 = point_cloud_relation(8, 1, 0.0);
        let mut c2 = point_cloud_relation(8, 2, 0.0);
        c2.scale(3.0); // different scale ⇒ genuinely different geometry
        let a = uniform(8);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let cfg = Alg1Config::default();
        let r = pga_gw(&p, GroundCost::L2, &cfg);
        assert!(r.value > 0.01, "GW = {}", r.value);
    }

    #[test]
    fn plan_is_feasible() {
        let c1 = point_cloud_relation(6, 5, 0.0);
        let c2 = point_cloud_relation(9, 6, 1.0);
        let a = uniform(6);
        let b = uniform(9);
        let p = GwProblem::new(&c1, &c2, &a, &b);
        let cfg = Alg1Config { inner_iters: 300, ..Default::default() };
        let r = egw(&p, GroundCost::L2, &cfg);
        let rows = r.plan.row_sums();
        let cols = r.plan.col_sums();
        for (x, y) in rows.iter().zip(&a) {
            assert!((x - y).abs() < 1e-3, "row marginal {x} vs {y}");
        }
        for (x, y) in cols.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3, "col marginal {x} vs {y}");
        }
    }

    #[test]
    fn emd_gw_runs_and_is_feasible() {
        let c1 = point_cloud_relation(6, 7, 0.0);
        let c2 = point_cloud_relation(6, 8, 0.5);
        let a = uniform(6);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let cfg = Alg1Config { epsilon: 0.0, outer_iters: 15, inner_iters: 0, tol: 1e-10 };
        let r = emd_gw(&p, GroundCost::L2, &cfg);
        assert!(r.value >= -1e-10);
        let rows = r.plan.row_sums();
        for (x, y) in rows.iter().zip(&a) {
            assert!((x - y).abs() < 1e-7);
        }
    }

    #[test]
    fn egw_and_pga_agree_roughly() {
        // Both approximate the same objective; values should be in the same
        // ballpark on an easy instance.
        let c1 = point_cloud_relation(8, 9, 0.0);
        let c2 = point_cloud_relation(8, 10, 0.3);
        let a = uniform(8);
        let p = GwProblem::new(&c1, &c2, &a, &a);
        let cfg = Alg1Config { epsilon: 0.01, outer_iters: 40, inner_iters: 80, tol: 1e-10 };
        let r1 = egw(&p, GroundCost::L2, &cfg);
        let r2 = pga_gw(&p, GroundCost::L2, &cfg);
        let denom = r1.value.abs().max(r2.value.abs()).max(1e-6);
        assert!(
            (r1.value - r2.value).abs() / denom < 0.5,
            "egw {} vs pga {}",
            r1.value,
            r2.value
        );
    }
}
