//! Ground cost functions `L : R × R → R` for the GW objective.
//!
//! The paper's key generality claim is that Spar-GW handles *arbitrary*
//! ground costs, whereas the decomposable-only baselines (EGW with the
//! Peyré trick, LR-GW, …) require
//! `L(x, y) = f1(x) + f2(y) − h1(x) h2(y)`.
//! ℓ2 and KL admit such decompositions; ℓ1 does not.

/// Ground cost selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GroundCost {
    /// ℓ1 loss `|x − y|` — indecomposable; the stress case of the paper.
    L1,
    /// Squared ℓ2 loss `(x − y)²` — decomposable.
    L2,
    /// KL divergence `x log(x/y) − x + y` (x, y > 0) — decomposable.
    Kl,
}

/// Decomposition `L(x,y) = f1(x) + f2(y) − h1(x)·h2(y)` (Peyré et al. 2016).
pub struct Decomposition {
    pub f1: fn(f64) -> f64,
    pub f2: fn(f64) -> f64,
    pub h1: fn(f64) -> f64,
    pub h2: fn(f64) -> f64,
}

impl GroundCost {
    /// Evaluate the cost on a pair of relation values.
    #[inline]
    pub fn eval(self, x: f64, y: f64) -> f64 {
        match self {
            GroundCost::L1 => (x - y).abs(),
            GroundCost::L2 => {
                let d = x - y;
                d * d
            }
            GroundCost::Kl => {
                // 0 log 0 := 0; guard y for padded zeros.
                if x <= 0.0 {
                    y
                } else {
                    x * (x / y.max(1e-300)).ln() - x + y
                }
            }
        }
    }

    /// The `(f1,f2,h1,h2)` decomposition if one exists.
    pub fn decomposition(self) -> Option<Decomposition> {
        match self {
            GroundCost::L1 => None,
            GroundCost::L2 => Some(Decomposition {
                // (x−y)² = x² + y² − (x)(2y)
                f1: |x| x * x,
                f2: |y| y * y,
                h1: |x| x,
                h2: |y| 2.0 * y,
            }),
            GroundCost::Kl => Some(Decomposition {
                // x log x − x + y − x·log y
                f1: |x| if x > 0.0 { x * x.ln() - x } else { 0.0 },
                f2: |y| y,
                h1: |x| x,
                h2: |y| y.max(1e-300).ln(),
            }),
        }
    }

    /// True if a decomposition exists (drives the fast dense path).
    pub fn is_decomposable(self) -> bool {
        !matches!(self, GroundCost::L1)
    }

    /// Short display name used by the bench harness.
    pub fn name(self) -> &'static str {
        match self {
            GroundCost::L1 => "l1",
            GroundCost::L2 => "l2",
            GroundCost::Kl => "kl",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_basics() {
        assert_eq!(GroundCost::L1.eval(3.0, 1.0), 2.0);
        assert_eq!(GroundCost::L2.eval(3.0, 1.0), 4.0);
        assert!(GroundCost::Kl.eval(1.0, 1.0).abs() < 1e-12);
        assert!(GroundCost::Kl.eval(2.0, 1.0) > 0.0);
    }

    #[test]
    fn decompositions_reconstruct_cost() {
        for cost in [GroundCost::L2, GroundCost::Kl] {
            let d = cost.decomposition().unwrap();
            for &x in &[0.3, 1.0, 2.5] {
                for &y in &[0.2, 1.0, 3.0] {
                    let direct = cost.eval(x, y);
                    let via = (d.f1)(x) + (d.f2)(y) - (d.h1)(x) * (d.h2)(y);
                    assert!(
                        (direct - via).abs() < 1e-12,
                        "{cost:?} at ({x},{y}): {direct} vs {via}"
                    );
                }
            }
        }
    }

    #[test]
    fn l1_not_decomposable() {
        assert!(GroundCost::L1.decomposition().is_none());
        assert!(!GroundCost::L1.is_decomposable());
        assert!(GroundCost::L2.is_decomposable());
    }

    #[test]
    fn costs_nonnegative() {
        for cost in [GroundCost::L1, GroundCost::L2, GroundCost::Kl] {
            for &x in &[0.1, 0.9, 4.0] {
                for &y in &[0.1, 1.1, 5.0] {
                    assert!(cost.eval(x, y) >= -1e-12, "{cost:?}({x},{y})");
                }
            }
        }
    }
}
