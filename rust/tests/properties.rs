//! Property-based tests over the library's core invariants, driven by the
//! seeded [`spargw::testutil::forall`] harness.

use std::collections::BTreeMap;

use spargw::coordinator::cache::StructureCache;
use spargw::coordinator::engine::{EngineConfig, PairwiseEngine};
use spargw::coordinator::service::PairwiseConfig;
use spargw::datasets::graphsets::imdb_b;
use spargw::gw::lr_gw::{lr_gw_factored, LrGwConfig};
use spargw::gw::qgw;
use spargw::gw::sampling::{sample_poisson, GwSampler, SideFactors};
use spargw::gw::solver::SolverBase;
use spargw::gw::spar_gw::{spar_gw, SparGwConfig};
use spargw::gw::tensor::{
    gw_energy, tensor_product_decomposable, tensor_product_generic, SparseCostContext,
};
use spargw::gw::{GroundCost, GwProblem};
use spargw::linalg::Mat;
use spargw::ot::{emd, sinkhorn, sparse_sinkhorn};
use spargw::rng::{AliasTable, Xoshiro256};
use spargw::sparse::Coo;
use spargw::testutil::{check_marginals, forall, random_relation, random_simplex};

struct Inst {
    cx: Mat,
    cy: Mat,
    a: Vec<f64>,
    b: Vec<f64>,
}

impl std::fmt::Debug for Inst {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Inst(m={}, n={})", self.a.len(), self.b.len())
    }
}

fn gen_inst(rng: &mut Xoshiro256) -> Inst {
    let m = 6 + rng.usize(10);
    let n = 6 + rng.usize(10);
    Inst {
        cx: random_relation(rng, m),
        cy: random_relation(rng, n),
        a: random_simplex(rng, m),
        b: random_simplex(rng, n),
    }
}

#[test]
fn prop_sinkhorn_plan_has_prescribed_marginals() {
    forall(
        "sinkhorn-marginals",
        0xA1,
        20,
        gen_inst,
        |inst| {
            let k = Mat::from_fn(inst.a.len(), inst.b.len(), |i, j| {
                (-(inst.cx[(i, i.min(inst.cx.cols() - 1))] + inst.cy[(j, 0)])).exp()
            });
            let res = sinkhorn(&inst.a, &inst.b, &k, 500, 1e-12);
            check_marginals(&res.plan, &inst.a, &inst.b, 1e-6)
        },
    );
}

#[test]
fn prop_sparse_sinkhorn_marginals_on_support() {
    forall(
        "sparse-sinkhorn-marginals",
        0xA2,
        20,
        |rng| {
            let inst = gen_inst(rng);
            let s = 8 * inst.a.len().max(inst.b.len());
            let sampler = GwSampler::new(&inst.a, &inst.b, 0.0);
            let set = sampler.sample_iid(rng, s);
            (inst, set)
        },
        |(inst, set)| {
            let vals: Vec<f64> = set.rows.iter().map(|_| 1.0).collect();
            let k = Coo::from_triplets(inst.a.len(), inst.b.len(), &set.rows, &set.cols, &vals);
            let (plan, _iters) = sparse_sinkhorn(&inst.a, &inst.b, &k, 2000, 1e-13);
            // The final scaling is the v-update, so *column* marginals are
            // exact on supported columns; rows converge only as far as the
            // sparse pattern permits (the restricted polytope may not
            // contain a exactly). Unsupported rows/cols carry no mass.
            let c = plan.col_sums();
            for (j, &cj) in c.iter().enumerate() {
                let has = set.cols.iter().any(|&y| y == j);
                if has && (cj - inst.b[j]).abs() > 1e-8 {
                    return Err(format!("col {j}: {cj} vs {}", inst.b[j]));
                }
                if !has && cj != 0.0 {
                    return Err(format!("unsupported col {j} has mass {cj}"));
                }
            }
            let r = plan.row_sums();
            for (i, &ri) in r.iter().enumerate() {
                let has = set.rows.iter().any(|&x| x == i);
                if has && (ri - inst.a[i]).abs() > 0.05 {
                    return Err(format!("row {i} far from marginal: {ri} vs {}", inst.a[i]));
                }
                if !has && ri != 0.0 {
                    return Err(format!("unsupported row {i} has mass {ri}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_emd_cost_below_sinkhorn_cost() {
    // The exact LP optimum lower-bounds any feasible (entropic) plan.
    forall(
        "emd-optimality",
        0xA3,
        15,
        gen_inst,
        |inst| {
            let cost = Mat::from_fn(inst.a.len(), inst.b.len(), |i, j| {
                inst.cx[(i, 0)] + inst.cy[(j, 0)] + (i as f64 * 0.7 + j as f64 * 1.3).sin().abs()
            });
            let ot = emd(&inst.a, &inst.b, &cost);
            check_marginals(&ot.plan, &inst.a, &inst.b, 1e-8)?;
            let k = cost.map(|c| (-c / 0.05).exp());
            let ent = sinkhorn(&inst.a, &inst.b, &k, 2000, 1e-12);
            let ent_cost = cost.frob_inner(&ent.plan);
            if ot.cost <= ent_cost + 1e-8 {
                Ok(())
            } else {
                Err(format!("LP {} > entropic {}", ot.cost, ent_cost))
            }
        },
    );
}

#[test]
fn prop_sampling_probabilities_normalized_and_bounded() {
    forall(
        "sampling-probs",
        0xA4,
        25,
        |rng| {
            let n = 5 + rng.usize(12);
            let a = random_simplex(rng, n);
            let b = random_simplex(rng, n);
            let shrink = rng.f64() * 0.5;
            (a, b, shrink)
        },
        |(a, b, shrink)| {
            let sampler = GwSampler::new(a, b, *shrink);
            let n = a.len();
            let total: f64 =
                (0..n).flat_map(|i| (0..n).map(move |j| (i, j))).map(|(i, j)| sampler.prob_of(i, j)).sum();
            if (total - 1.0).abs() > 1e-9 {
                return Err(format!("probabilities sum to {total}"));
            }
            // Shrinkage enforces (H.4): the product-form mixing in
            // GwSampler guarantees p_ij ≥ θ²/(mn) (c₃ = θ²).
            if *shrink > 0.0 {
                let floor = shrink * shrink / (n * n) as f64;
                for i in 0..n {
                    for j in 0..n {
                        if sampler.prob_of(i, j) < floor - 1e-12 {
                            return Err(format!("p[{i},{j}] below H.4 floor"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_poisson_sample_size_concentrates() {
    forall(
        "poisson-size",
        0xA5,
        10,
        |rng| {
            let n = 20;
            let a = random_simplex(rng, n);
            let b = random_simplex(rng, n);
            let s = 8 * n;
            let set = sample_poisson(rng, &a, &b, 0.0, s);
            (set.len(), s)
        },
        |(len, s)| {
            // E[|S|] ≤ s; allow generous concentration slack.
            if *len <= 2 * s && *len > s / 8 {
                Ok(())
            } else {
                Err(format!("|S| = {len} vs budget {s}"))
            }
        },
    );
}

#[test]
fn prop_sparse_cost_matches_dense_on_support() {
    // C̃(T̃) restricted to S equals the dense tensor product when T̃ is the
    // dense plan masked to S.
    forall(
        "sparse-cost-consistency",
        0xA6,
        15,
        |rng| {
            let inst = gen_inst(rng);
            let s = 6 * inst.a.len().max(inst.b.len());
            let sampler = GwSampler::new(&inst.a, &inst.b, 0.0);
            let set = sampler.sample_iid(rng, s);
            (inst, set)
        },
        |(inst, set)| {
            let cost = GroundCost::L1;
            let (m, n) = (inst.a.len(), inst.b.len());
            // T̃: arbitrary values on S, zero elsewhere.
            let t_vals: Vec<f64> = (0..set.len()).map(|l| 0.1 + 0.01 * l as f64).collect();
            let mut t_dense = Mat::zeros(m, n);
            for (l, (&i, &j)) in set.rows.iter().zip(&set.cols).enumerate() {
                t_dense[(i, j)] += t_vals[l];
            }
            let ctx = SparseCostContext::new(&inst.cx, &inst.cy, &set.rows, &set.cols, cost);
            let sparse_c = ctx.cost_values(&t_vals);
            let dense_c = tensor_product_generic(&inst.cx, &inst.cy, &t_dense, cost);
            for (l, (&i, &j)) in set.rows.iter().zip(&set.cols).enumerate() {
                let d = dense_c[(i, j)];
                if (sparse_c[l] - d).abs() > 3e-6 * d.abs().max(1.0) {
                    return Err(format!("S[{l}] = ({i},{j}): sparse {} vs dense {d}", sparse_c[l]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_decomposable_tensor_product_matches_generic() {
    forall(
        "peyre-decomposition",
        0xA7,
        15,
        gen_inst,
        |inst| {
            let t = Mat::outer(&inst.a, &inst.b);
            for cost in [GroundCost::L2, GroundCost::Kl] {
                let fast = tensor_product_decomposable(&inst.cx, &inst.cy, &t, cost);
                let slow = tensor_product_generic(&inst.cx, &inst.cy, &t, cost);
                for (x, y) in fast.data().iter().zip(slow.data()) {
                    if (x - y).abs() > 1e-8 * y.abs().max(1.0) {
                        return Err(format!("{}: {x} vs {y}", cost.name()));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_spar_gw_plan_is_feasible_and_supported() {
    forall(
        "spar-gw-feasibility",
        0xA8,
        10,
        gen_inst,
        |inst| {
            let p = GwProblem::new(&inst.cx, &inst.cy, &inst.a, &inst.b);
            let cfg = SparGwConfig {
                sample_size: 12 * inst.a.len().max(inst.b.len()),
                ..Default::default()
            };
            let mut rng = Xoshiro256::new(42);
            let res = spar_gw(&p, GroundCost::L2, &cfg, &mut rng);
            if !res.value.is_finite() || res.value < -1e-9 {
                return Err(format!("value {}", res.value));
            }
            // Plan mass ≈ 1 and value consistent with the plan's energy.
            let mass = res.plan.sum();
            if (mass - 1.0).abs() > 0.05 {
                return Err(format!("plan mass {mass}"));
            }
            let energy = gw_energy(&inst.cx, &inst.cy, &res.plan.to_dense(), GroundCost::L2);
            if (energy - res.value).abs() > 1e-6 * energy.abs().max(1e-9) {
                return Err(format!("value {} vs recomputed energy {energy}", res.value));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_structure_cache_matches_fresh_computation() {
    // Cached per-structure state is a pure amortization: relation
    // matrices and marginals equal freshly computed ones, and a sampler
    // assembled from cached factors draws the exact same index sets as
    // one built from the raw marginals.
    forall(
        "structure-cache-consistency",
        0xB1,
        8,
        |rng| {
            let mut ds = imdb_b(rng.next_u64());
            let keep = 3 + rng.usize(4);
            ds.graphs.truncate(keep);
            ds
        },
        |ds| {
            let cache = StructureCache::build(ds);
            for (i, g) in ds.graphs.iter().enumerate() {
                let e = cache.get(i);
                if e.marginal != g.marginal() {
                    return Err(format!("structure {i}: cached marginal differs"));
                }
                if e.len() != g.n_nodes() {
                    return Err(format!("structure {i}: cached length differs"));
                }
            }
            // Pairwise: cached factors reproduce the fresh sampler's draws
            // bit-for-bit under identical RNG streams.
            for i in 0..ds.len() {
                for j in (i + 1)..ds.len() {
                    let (sx, sy) = (cache.get(i), cache.get(j));
                    let fresh = GwSampler::new(&sx.marginal, &sy.marginal, 0.0);
                    let cached = GwSampler::from_factors(&sx.factors, &sy.factors, 0.0);
                    let mut r1 = Xoshiro256::new(91);
                    let mut r2 = Xoshiro256::new(91);
                    let s1 = fresh.sample_iid(&mut r1, 128);
                    let s2 = cached.sample_iid(&mut r2, 128);
                    if s1.rows != s2.rows || s1.cols != s2.cols {
                        return Err(format!("pair ({i},{j}): cached draws differ"));
                    }
                    for (l, (w1, w2)) in s1.weights.iter().zip(&s2.weights).enumerate() {
                        if w1.to_bits() != w2.to_bits() {
                            return Err(format!(
                                "pair ({i},{j}) weight {l}: {w1} vs {w2}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_side_factors_preserve_eq5_probabilities() {
    forall(
        "side-factors-probabilities",
        0xB2,
        15,
        |rng| {
            let m = 4 + rng.usize(10);
            let n = 4 + rng.usize(10);
            (random_simplex(rng, m), random_simplex(rng, n))
        },
        |(a, b)| {
            let fresh = GwSampler::new(a, b, 0.0);
            let cached =
                GwSampler::from_factors(&SideFactors::new(a), &SideFactors::new(b), 0.0);
            for i in 0..a.len() {
                for j in 0..b.len() {
                    let (p1, p2) = (fresh.prob_of(i, j), cached.prob_of(i, j));
                    if p1.to_bits() != p2.to_bits() {
                        return Err(format!("p({i},{j}): {p1} vs {p2}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gram_symmetric_zero_diagonal_for_balanced_solvers() {
    // The engine's Gram output for the balanced solvers is symmetric with
    // a zero diagonal and finite everywhere, for every shard count.
    forall(
        "gram-symmetry",
        0xB3,
        2,
        |rng| {
            let mut ds = imdb_b(rng.next_u64());
            ds.graphs.truncate(5);
            (ds, 1 + rng.usize(3))
        },
        |(ds, shards)| {
            for solver in ["spar_gw", "spar_fgw"] {
                let cfg = PairwiseConfig {
                    solver: solver.to_string(),
                    seed: 9,
                    spar: SparGwConfig {
                        sample_size: 48,
                        outer_iters: 2,
                        inner_iters: 4,
                        ..Default::default()
                    },
                    ..Default::default()
                };
                let opts = EngineConfig { shards: *shards, ..Default::default() };
                let g = PairwiseEngine::new(cfg, opts)
                    .gram(ds)
                    .map_err(|e| format!("{solver}: {e}"))?;
                let n = ds.len();
                for i in 0..n {
                    if g.distances[(i, i)] != 0.0 {
                        return Err(format!("{solver}: diag[{i}] nonzero"));
                    }
                    for j in 0..n {
                        let (x, y) = (g.distances[(i, j)], g.distances[(j, i)]);
                        if x.to_bits() != y.to_bits() {
                            return Err(format!("{solver}: asymmetry at ({i},{j})"));
                        }
                        if !x.is_finite() {
                            return Err(format!("{solver}: non-finite at ({i},{j})"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_qgw_extension_preserves_exact_coarse_marginals() {
    // With an exact inner solver (emd_gw) the coarse plan's marginals are
    // exact, and the northwest-corner extension distributes each
    // partition's coarse mass over its members proportionally to the input
    // marginal — so the extended sparse plan reproduces the *input*
    // marginals to floating-point error while never materializing n².
    forall(
        "qgw-extension-marginals",
        0xB4,
        8,
        gen_inst,
        |inst| {
            let p = GwProblem::new(&inst.cx, &inst.cy, &inst.a, &inst.b);
            let mut opts = BTreeMap::new();
            opts.insert("inner".to_string(), "emd_gw".to_string());
            let solver =
                qgw::build(&opts, &SolverBase::default()).map_err(|e| format!("{e}"))?;
            let mut rng = Xoshiro256::new(13);
            let mut ws = spargw::gw::core::Workspace::new();
            let r = solver.solve(&p, &mut rng, &mut ws).map_err(|e| format!("{e}"))?;
            if !r.value.is_finite() || r.value < -1e-9 {
                return Err(format!("value {}", r.value));
            }
            if !r.plan.is_finite() || r.plan.nnz() == 0 {
                return Err(format!("degenerate plan (nnz {})", r.plan.nnz()));
            }
            let mass = r.plan.sum();
            if (mass - 1.0).abs() > 1e-9 {
                return Err(format!("plan mass {mass}"));
            }
            for (i, (x, y)) in r.plan.row_sums().iter().zip(&inst.a).enumerate() {
                if (x - y).abs() > 1e-8 {
                    return Err(format!("row {i}: {x} vs {y}"));
                }
            }
            for (j, (x, y)) in r.plan.col_sums().iter().zip(&inst.b).enumerate() {
                if (x - y).abs() > 1e-8 {
                    return Err(format!("col {j}: {x} vs {y}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lr_gw_factored_objective_and_marginals_consistent() {
    // The factored mirror-descent path never materializes the coupling;
    // its factor-side objective and marginals must agree with the ones
    // recomputed from the dense reconstruction T = Q diag(1/g) Rᵀ, and the
    // Sinkhorn projections must keep the factors (hence T) feasible.
    forall(
        "lr-gw-factored-consistency",
        0xB5,
        6,
        gen_inst,
        |inst| {
            let p = GwProblem::new(&inst.cx, &inst.cy, &inst.a, &inst.b);
            let cfg = LrGwConfig { outer_iters: 8, ..Default::default() };
            let r = lr_gw_factored(&p, GroundCost::L2, &cfg);
            if !r.value.is_finite() {
                return Err(format!("value {}", r.value));
            }
            let t = r.plan.reconstruct();
            let dense = gw_energy(&inst.cx, &inst.cy, &t, GroundCost::L2);
            if (r.value - dense).abs() > 1e-7 * dense.abs().max(1.0) {
                return Err(format!("factored {} vs dense energy {dense}", r.value));
            }
            let mass = r.plan.sum();
            if (mass - 1.0).abs() > 1e-6 {
                return Err(format!("plan mass {mass}"));
            }
            for (i, (x, y)) in r.plan.row_sums().iter().zip(&inst.a).enumerate() {
                if (x - y).abs() > 1e-3 {
                    return Err(format!("row {i}: {x} vs {y}"));
                }
            }
            for (j, (x, y)) in r.plan.col_sums().iter().zip(&inst.b).enumerate() {
                if (x - y).abs() > 1e-3 {
                    return Err(format!("col {j}: {x} vs {y}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_alias_table_reproduces_distribution() {
    forall(
        "alias-distribution",
        0xA9,
        8,
        |rng| {
            let n = 4 + rng.usize(8);
            random_simplex(rng, n)
        },
        |w| {
            let alias = AliasTable::new(w);
            let mut rng = Xoshiro256::new(77);
            let draws = 200_000;
            let mut counts = vec![0usize; w.len()];
            for _ in 0..draws {
                counts[alias.sample(&mut rng)] += 1;
            }
            for (i, (&c, &wi)) in counts.iter().zip(w.iter()).enumerate() {
                let freq = c as f64 / draws as f64;
                if (freq - wi).abs() > 0.02 + 3.0 * (wi / draws as f64).sqrt() {
                    return Err(format!("bin {i}: freq {freq} vs weight {wi}"));
                }
            }
            Ok(())
        },
    );
}
