//! Property-based tests over the library's core invariants, driven by the
//! seeded [`spargw::testutil::forall`] harness.

use spargw::gw::sampling::{sample_poisson, GwSampler};
use spargw::gw::spar_gw::{spar_gw, SparGwConfig};
use spargw::gw::tensor::{
    gw_energy, tensor_product_decomposable, tensor_product_generic, SparseCostContext,
};
use spargw::gw::{GroundCost, GwProblem};
use spargw::linalg::Mat;
use spargw::ot::{emd, sinkhorn, sparse_sinkhorn};
use spargw::rng::{AliasTable, Xoshiro256};
use spargw::sparse::Coo;
use spargw::testutil::{check_marginals, forall, random_relation, random_simplex};

struct Inst {
    cx: Mat,
    cy: Mat,
    a: Vec<f64>,
    b: Vec<f64>,
}

impl std::fmt::Debug for Inst {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Inst(m={}, n={})", self.a.len(), self.b.len())
    }
}

fn gen_inst(rng: &mut Xoshiro256) -> Inst {
    let m = 6 + rng.usize(10);
    let n = 6 + rng.usize(10);
    Inst {
        cx: random_relation(rng, m),
        cy: random_relation(rng, n),
        a: random_simplex(rng, m),
        b: random_simplex(rng, n),
    }
}

#[test]
fn prop_sinkhorn_plan_has_prescribed_marginals() {
    forall(
        "sinkhorn-marginals",
        0xA1,
        20,
        gen_inst,
        |inst| {
            let k = Mat::from_fn(inst.a.len(), inst.b.len(), |i, j| {
                (-(inst.cx[(i, i.min(inst.cx.cols() - 1))] + inst.cy[(j, 0)])).exp()
            });
            let res = sinkhorn(&inst.a, &inst.b, &k, 500, 1e-12);
            check_marginals(&res.plan, &inst.a, &inst.b, 1e-6)
        },
    );
}

#[test]
fn prop_sparse_sinkhorn_marginals_on_support() {
    forall(
        "sparse-sinkhorn-marginals",
        0xA2,
        20,
        |rng| {
            let inst = gen_inst(rng);
            let s = 8 * inst.a.len().max(inst.b.len());
            let mut sampler = GwSampler::new(&inst.a, &inst.b, 0.0);
            let set = sampler.sample_iid(rng, s);
            (inst, set)
        },
        |(inst, set)| {
            let vals: Vec<f64> = set.rows.iter().map(|_| 1.0).collect();
            let k = Coo::from_triplets(inst.a.len(), inst.b.len(), &set.rows, &set.cols, &vals);
            let (plan, _iters) = sparse_sinkhorn(&inst.a, &inst.b, &k, 2000, 1e-13);
            // The final scaling is the v-update, so *column* marginals are
            // exact on supported columns; rows converge only as far as the
            // sparse pattern permits (the restricted polytope may not
            // contain a exactly). Unsupported rows/cols carry no mass.
            let c = plan.col_sums();
            for (j, &cj) in c.iter().enumerate() {
                let has = set.cols.iter().any(|&y| y == j);
                if has && (cj - inst.b[j]).abs() > 1e-8 {
                    return Err(format!("col {j}: {cj} vs {}", inst.b[j]));
                }
                if !has && cj != 0.0 {
                    return Err(format!("unsupported col {j} has mass {cj}"));
                }
            }
            let r = plan.row_sums();
            for (i, &ri) in r.iter().enumerate() {
                let has = set.rows.iter().any(|&x| x == i);
                if has && (ri - inst.a[i]).abs() > 0.05 {
                    return Err(format!("row {i} far from marginal: {ri} vs {}", inst.a[i]));
                }
                if !has && ri != 0.0 {
                    return Err(format!("unsupported row {i} has mass {ri}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_emd_cost_below_sinkhorn_cost() {
    // The exact LP optimum lower-bounds any feasible (entropic) plan.
    forall(
        "emd-optimality",
        0xA3,
        15,
        gen_inst,
        |inst| {
            let cost = Mat::from_fn(inst.a.len(), inst.b.len(), |i, j| {
                inst.cx[(i, 0)] + inst.cy[(j, 0)] + (i as f64 * 0.7 + j as f64 * 1.3).sin().abs()
            });
            let ot = emd(&inst.a, &inst.b, &cost);
            check_marginals(&ot.plan, &inst.a, &inst.b, 1e-8)?;
            let k = cost.map(|c| (-c / 0.05).exp());
            let ent = sinkhorn(&inst.a, &inst.b, &k, 2000, 1e-12);
            let ent_cost = cost.frob_inner(&ent.plan);
            if ot.cost <= ent_cost + 1e-8 {
                Ok(())
            } else {
                Err(format!("LP {} > entropic {}", ot.cost, ent_cost))
            }
        },
    );
}

#[test]
fn prop_sampling_probabilities_normalized_and_bounded() {
    forall(
        "sampling-probs",
        0xA4,
        25,
        |rng| {
            let n = 5 + rng.usize(12);
            let a = random_simplex(rng, n);
            let b = random_simplex(rng, n);
            let shrink = rng.f64() * 0.5;
            (a, b, shrink)
        },
        |(a, b, shrink)| {
            let sampler = GwSampler::new(a, b, *shrink);
            let n = a.len();
            let total: f64 =
                (0..n).flat_map(|i| (0..n).map(move |j| (i, j))).map(|(i, j)| sampler.prob_of(i, j)).sum();
            if (total - 1.0).abs() > 1e-9 {
                return Err(format!("probabilities sum to {total}"));
            }
            // Shrinkage enforces (H.4): the product-form mixing in
            // GwSampler guarantees p_ij ≥ θ²/(mn) (c₃ = θ²).
            if *shrink > 0.0 {
                let floor = shrink * shrink / (n * n) as f64;
                for i in 0..n {
                    for j in 0..n {
                        if sampler.prob_of(i, j) < floor - 1e-12 {
                            return Err(format!("p[{i},{j}] below H.4 floor"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_poisson_sample_size_concentrates() {
    forall(
        "poisson-size",
        0xA5,
        10,
        |rng| {
            let n = 20;
            let a = random_simplex(rng, n);
            let b = random_simplex(rng, n);
            let s = 8 * n;
            let set = sample_poisson(rng, &a, &b, 0.0, s);
            (set.len(), s)
        },
        |(len, s)| {
            // E[|S|] ≤ s; allow generous concentration slack.
            if *len <= 2 * s && *len > s / 8 {
                Ok(())
            } else {
                Err(format!("|S| = {len} vs budget {s}"))
            }
        },
    );
}

#[test]
fn prop_sparse_cost_matches_dense_on_support() {
    // C̃(T̃) restricted to S equals the dense tensor product when T̃ is the
    // dense plan masked to S.
    forall(
        "sparse-cost-consistency",
        0xA6,
        15,
        |rng| {
            let inst = gen_inst(rng);
            let s = 6 * inst.a.len().max(inst.b.len());
            let mut sampler = GwSampler::new(&inst.a, &inst.b, 0.0);
            let set = sampler.sample_iid(rng, s);
            (inst, set)
        },
        |(inst, set)| {
            let cost = GroundCost::L1;
            let (m, n) = (inst.a.len(), inst.b.len());
            // T̃: arbitrary values on S, zero elsewhere.
            let t_vals: Vec<f64> = (0..set.len()).map(|l| 0.1 + 0.01 * l as f64).collect();
            let mut t_dense = Mat::zeros(m, n);
            for (l, (&i, &j)) in set.rows.iter().zip(&set.cols).enumerate() {
                t_dense[(i, j)] += t_vals[l];
            }
            let ctx = SparseCostContext::new(&inst.cx, &inst.cy, &set.rows, &set.cols, cost);
            let sparse_c = ctx.cost_values(&t_vals);
            let dense_c = tensor_product_generic(&inst.cx, &inst.cy, &t_dense, cost);
            for (l, (&i, &j)) in set.rows.iter().zip(&set.cols).enumerate() {
                let d = dense_c[(i, j)];
                if (sparse_c[l] - d).abs() > 3e-6 * d.abs().max(1.0) {
                    return Err(format!("S[{l}] = ({i},{j}): sparse {} vs dense {d}", sparse_c[l]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_decomposable_tensor_product_matches_generic() {
    forall(
        "peyre-decomposition",
        0xA7,
        15,
        gen_inst,
        |inst| {
            let t = Mat::outer(&inst.a, &inst.b);
            for cost in [GroundCost::L2, GroundCost::Kl] {
                let fast = tensor_product_decomposable(&inst.cx, &inst.cy, &t, cost);
                let slow = tensor_product_generic(&inst.cx, &inst.cy, &t, cost);
                for (x, y) in fast.data().iter().zip(slow.data()) {
                    if (x - y).abs() > 1e-8 * y.abs().max(1.0) {
                        return Err(format!("{}: {x} vs {y}", cost.name()));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_spar_gw_plan_is_feasible_and_supported() {
    forall(
        "spar-gw-feasibility",
        0xA8,
        10,
        gen_inst,
        |inst| {
            let p = GwProblem::new(&inst.cx, &inst.cy, &inst.a, &inst.b);
            let cfg = SparGwConfig {
                sample_size: 12 * inst.a.len().max(inst.b.len()),
                ..Default::default()
            };
            let mut rng = Xoshiro256::new(42);
            let res = spar_gw(&p, GroundCost::L2, &cfg, &mut rng);
            if !res.value.is_finite() || res.value < -1e-9 {
                return Err(format!("value {}", res.value));
            }
            // Plan mass ≈ 1 and value consistent with the plan's energy.
            let mass = res.plan.sum();
            if (mass - 1.0).abs() > 0.05 {
                return Err(format!("plan mass {mass}"));
            }
            let energy = gw_energy(&inst.cx, &inst.cy, &res.plan.to_dense(), GroundCost::L2);
            if (energy - res.value).abs() > 1e-6 * energy.abs().max(1e-9) {
                return Err(format!("value {} vs recomputed energy {energy}", res.value));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_alias_table_reproduces_distribution() {
    forall(
        "alias-distribution",
        0xA9,
        8,
        |rng| {
            let n = 4 + rng.usize(8);
            random_simplex(rng, n)
        },
        |w| {
            let mut alias = AliasTable::new(w);
            let mut rng = Xoshiro256::new(77);
            let draws = 200_000;
            let mut counts = vec![0usize; w.len()];
            for _ in 0..draws {
                counts[alias.sample(&mut rng)] += 1;
            }
            for (i, (&c, &wi)) in counts.iter().zip(w.iter()).enumerate() {
                let freq = c as f64 / draws as f64;
                if (freq - wi).abs() > 0.02 + 3.0 * (wi / draws as f64).sqrt() {
                    return Err(format!("bin {i}: freq {freq} vs weight {wi}"));
                }
            }
            Ok(())
        },
    );
}
