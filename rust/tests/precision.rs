//! Mixed-precision suite: the `precision=f32|f64` registry option.
//!
//! Three contracts, in order of strictness:
//!
//! 1. **f64 golden parity** — `precision=f64` (and the default) is
//!    bit-identical to the historical path for *all ten* registered
//!    solvers: same value bits, same plan mass bits, same iteration
//!    counts under identical RNG streams.
//! 2. **f32 tolerance** — on the gaussian and moon workloads the f32
//!    Spar-GW estimate lands within a stated tolerance of the f64
//!    estimate: 5% on a shared sampled set (pure rounding difference),
//!    35% (with an absolute floor) across independently sampled runs
//!    (rounding + sampling noise).
//! 3. **Descriptive rejection** — f64-only solvers reject
//!    `precision=f32` with a one-line error naming the supported values.
//!
//! Run standalone in CI: `cargo test --release --test precision`.

use std::collections::BTreeMap;

use spargw::datasets;
use spargw::gw::core::Workspace;
use spargw::gw::solver::{SolverBase, SolverRegistry};
use spargw::gw::spar_gw::{spar_gw_with_workspace, spar_gw_with_workspace_f32, SparGwConfig};
use spargw::gw::sampling::GwSampler;
use spargw::gw::GroundCost;
use spargw::rng::Xoshiro256;
use spargw::util::mean;

fn opts(kv: &[(&str, &str)]) -> BTreeMap<String, String> {
    kv.iter().map(|&(k, v)| (k.to_string(), v.to_string())).collect()
}

fn smoke_base() -> SolverBase {
    SolverBase { outer_iters: 6, inner_iters: 60, ..Default::default() }
}

/// Per-solver overrides mirroring `registry_smoke` (LR-GW keeps its own
/// mirror-descent schedule unless pinned).
fn extra_opts(name: &str) -> Vec<(&'static str, &'static str)> {
    if name == "lr_gw" {
        vec![("outer", "6")]
    } else {
        Vec::new()
    }
}

#[test]
fn precision_f64_is_bit_identical_for_every_solver() {
    let n = 12;
    let mut rng0 = Xoshiro256::new(0xF0);
    let inst = datasets::gaussian::gaussian(n, &mut rng0);
    let p = inst.problem();
    let base = smoke_base();

    for &name in SolverRegistry::names() {
        let mut plain_opts = extra_opts(name);
        let default_solver =
            SolverRegistry::build_with_base(name, &opts(&plain_opts), &base).unwrap();
        plain_opts.push(("precision", "f64"));
        let f64_solver =
            SolverRegistry::build_with_base(name, &opts(&plain_opts), &base).unwrap();

        let mut rng1 = Xoshiro256::new(7);
        let mut rng2 = Xoshiro256::new(7);
        let mut ws1 = Workspace::new();
        let mut ws2 = Workspace::new();
        let r1 = default_solver
            .solve(&p, &mut rng1, &mut ws1)
            .unwrap_or_else(|e| panic!("{name}: default solve failed: {e}"));
        let r2 = f64_solver
            .solve(&p, &mut rng2, &mut ws2)
            .unwrap_or_else(|e| panic!("{name}: precision=f64 solve failed: {e}"));

        assert_eq!(
            r1.value.to_bits(),
            r2.value.to_bits(),
            "{name}: precision=f64 changed the value ({} vs {})",
            r1.value,
            r2.value
        );
        assert_eq!(r1.outer_iters, r2.outer_iters, "{name}: outer iters changed");
        assert_eq!(r1.converged, r2.converged, "{name}: converged flag changed");
        assert_eq!(r1.plan.nnz(), r2.plan.nnz(), "{name}: plan support changed");
        assert_eq!(
            r1.plan.sum().to_bits(),
            r2.plan.sum().to_bits(),
            "{name}: plan mass changed"
        );
    }
}

/// Same sampled set, same schedule: the f32 engine differs from f64 only
/// by rounding. 5% is generous (observed drift is ~1e-4 relative).
#[test]
fn f32_tracks_f64_on_a_shared_set_gaussian_and_moon() {
    for (label, seed) in [("gaussian", 0xA1u64), ("moon", 0xA2u64)] {
        let n = 36;
        let mut rng0 = Xoshiro256::new(seed);
        let inst = match label {
            "gaussian" => datasets::gaussian::gaussian(n, &mut rng0),
            _ => datasets::moon::moon(n, &mut rng0),
        };
        let p = inst.problem();
        let sampler = GwSampler::new(p.a, p.b, 0.0);
        let mut rng = Xoshiro256::new(seed ^ 0x55);
        let set = sampler.sample_iid(&mut rng, 12 * n);
        let cfg = SparGwConfig { sample_size: 12 * n, ..Default::default() };
        let mut ws = Workspace::new();
        let r64 = spar_gw_with_workspace(&p, GroundCost::L2, &cfg, &set, &mut ws);
        let r32 = spar_gw_with_workspace_f32(&p, GroundCost::L2, &cfg, &set, &mut ws);
        assert!(r32.value.is_finite(), "{label}: f32 value not finite");
        let denom = r64.value.abs().max(1e-3);
        let rel = (r32.value - r64.value).abs() / denom;
        assert!(
            rel < 0.05,
            "{label}: f32 {} vs f64 {} (rel {rel})",
            r32.value,
            r64.value
        );
    }
}

/// Independently sampled runs (the registry path: f32 rounds the
/// sampling factors too, so the index sets differ): means over several
/// seeds agree within sampling noise plus rounding.
#[test]
fn f32_registry_estimates_track_f64_across_samples() {
    for (label, seed) in [("gaussian", 0xB1u64), ("moon", 0xB2u64)] {
        let n = 36;
        let mut rng0 = Xoshiro256::new(seed);
        let inst = match label {
            "gaussian" => datasets::gaussian::gaussian(n, &mut rng0),
            _ => datasets::moon::moon(n, &mut rng0),
        };
        let p = inst.problem();
        let base = smoke_base();
        let s64 = SolverRegistry::build_with_base("spar_gw", &opts(&[]), &base).unwrap();
        let s32 = SolverRegistry::build_with_base(
            "spar_gw",
            &opts(&[("precision", "f32")]),
            &base,
        )
        .unwrap();

        let mut vals64 = Vec::new();
        let mut vals32 = Vec::new();
        for k in 0..6u64 {
            let mut ws = Workspace::new();
            let mut r1 = Xoshiro256::new(seed ^ (1000 + k));
            vals64.push(s64.solve(&p, &mut r1, &mut ws).unwrap().value);
            let mut r2 = Xoshiro256::new(seed ^ (1000 + k));
            vals32.push(s32.solve(&p, &mut r2, &mut ws).unwrap().value);
        }
        let m64 = mean(&vals64);
        let m32 = mean(&vals32);
        assert!(vals32.iter().all(|v| v.is_finite()), "{label}: non-finite f32 value");
        let tol = 0.35 * m64.abs().max(0.02);
        assert!(
            (m32 - m64).abs() < tol,
            "{label}: f32 mean {m32} vs f64 mean {m64} (tol {tol})"
        );
    }
}

#[test]
fn spar_family_accepts_f32_and_dense_solvers_reject_it() {
    let f32_opts = opts(&[("precision", "f32")]);
    for &name in SolverRegistry::names() {
        let r = SolverRegistry::build_with_base(name, &f32_opts, &smoke_base());
        if SolverRegistry::supports_f32(name) {
            assert!(r.is_ok(), "{name}: must accept precision=f32");
        } else {
            let msg = format!("{}", r.unwrap_err());
            assert!(!msg.contains('\n'), "{name}: error must be one line: {msg}");
            assert!(msg.contains(name), "{name}: error must name the solver: {msg}");
            assert!(msg.contains("f64"), "{name}: error must name the valid value: {msg}");
        }
    }
}

#[test]
fn spar_ugw_f32_runs_and_is_finite() {
    let n = 24;
    let mut rng0 = Xoshiro256::new(0xC3);
    let inst = datasets::gaussian::gaussian(n, &mut rng0);
    let p = inst.problem();
    let solver = SolverRegistry::build_with_base(
        "spar_ugw",
        &opts(&[("precision", "f32")]),
        &smoke_base(),
    )
    .unwrap();
    let mut rng = Xoshiro256::new(11);
    let mut ws = Workspace::new();
    let r = solver.solve(&p, &mut rng, &mut ws).unwrap();
    assert!(r.value.is_finite(), "value {}", r.value);
    assert!(r.plan.is_finite());
    assert!(r.plan.sum() > 0.0);
}

#[test]
fn malformed_precision_value_lists_choices() {
    let err = SolverRegistry::build_with_base(
        "spar_gw",
        &opts(&[("precision", "half")]),
        &smoke_base(),
    )
    .unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("precision"), "{msg}");
    assert!(msg.contains("f32") && msg.contains("f64"), "{msg}");
}
