//! Numerics-policy suite: the crate-wide `strict|fast` tier.
//!
//! Three contracts, in order of strictness:
//!
//! 1. **Strict default** — with no `SPARGW_NUMERICS` in the
//!    environment the resolved policy is strict, and an explicit
//!    strict override is bit-identical to the default path for every
//!    registered solver (same value bits, same plan mass bits, same
//!    iteration counts under identical RNG streams).
//! 2. **Fast tolerance** — under the fast tier (FMA contraction,
//!    polynomial exp, fused Sinkhorn sweeps) the GW objective of every
//!    registered solver lands within 1e-10 relative of its strict
//!    value, with identical iteration schedules (`tol = 0` pins them;
//!    fast never changes RNG streams, sampling, or chunk boundaries).
//! 3. **Fast determinism** — within the fast tier results are
//!    bit-identical across pool widths and across repeated runs: the
//!    tier relaxes per-element rounding only, never the reduction
//!    schedule.
//!
//! Run standalone in CI: `cargo test --release --test numerics`.

use std::collections::BTreeMap;

use spargw::datasets;
use spargw::gw::core::Workspace;
use spargw::gw::solver::{SolverBase, SolverRegistry};
use spargw::kernel::simd::{self, NumericsPolicy};
use spargw::rng::Xoshiro256;
use spargw::runtime::pool::with_thread_limit;

fn opts(kv: &[(&str, &str)]) -> BTreeMap<String, String> {
    kv.iter().map(|&(k, v)| (k.to_string(), v.to_string())).collect()
}

/// `tol = 0` disables outer early-stopping so the iteration schedule is
/// identical under both tiers and the values are directly comparable.
fn smoke_base() -> SolverBase {
    SolverBase { outer_iters: 6, inner_iters: 60, tol: 0.0, ..Default::default() }
}

/// Per-solver overrides mirroring the precision suite (LR-GW keeps its
/// own mirror-descent schedule unless pinned).
fn extra_opts(name: &str) -> Vec<(&'static str, &'static str)> {
    if name == "lr_gw" {
        vec![("outer", "6")]
    } else {
        Vec::new()
    }
}

/// One deterministic solve of `name` under `policy`: fresh RNG stream,
/// fresh workspace, shared gaussian instance.
fn solve_under(
    name: &str,
    policy: NumericsPolicy,
    p: &spargw::gw::GwProblem,
) -> spargw::gw::solver::SolveReport {
    let solver =
        SolverRegistry::build_with_base(name, &opts(&extra_opts(name)), &smoke_base()).unwrap();
    simd::with_numerics_override(policy, || {
        let mut rng = Xoshiro256::new(7);
        let mut ws = Workspace::new();
        solver
            .solve(p, &mut rng, &mut ws)
            .unwrap_or_else(|e| panic!("{name} under {}: solve failed: {e}", policy.name()))
    })
}

#[test]
fn default_policy_is_strict_and_bit_identical_to_explicit_strict() {
    // The resolved default consults SPARGW_NUMERICS, so this contract
    // only holds in a clean environment (the CI numerics matrix sets
    // the variable deliberately; there the fast tolerance test below
    // carries the load).
    if std::env::var_os("SPARGW_NUMERICS").is_some() {
        return;
    }
    assert_eq!(simd::current_numerics(), NumericsPolicy::Strict);

    let n = 12;
    let mut rng0 = Xoshiro256::new(0xF0);
    let inst = datasets::gaussian::gaussian(n, &mut rng0);
    let p = inst.problem();
    for &name in SolverRegistry::names() {
        let solver =
            SolverRegistry::build_with_base(name, &opts(&extra_opts(name)), &smoke_base())
                .unwrap();
        let mut rng1 = Xoshiro256::new(7);
        let mut ws1 = Workspace::new();
        let r_default = solver.solve(&p, &mut rng1, &mut ws1).unwrap();
        let r_strict = solve_under(name, NumericsPolicy::Strict, &p);
        assert_eq!(
            r_default.value.to_bits(),
            r_strict.value.to_bits(),
            "{name}: explicit strict changed the value ({} vs {})",
            r_default.value,
            r_strict.value
        );
        assert_eq!(r_default.outer_iters, r_strict.outer_iters, "{name}: outer iters changed");
        assert_eq!(
            r_default.plan.sum().to_bits(),
            r_strict.plan.sum().to_bits(),
            "{name}: plan mass changed"
        );
    }
}

/// The acceptance criterion: fast tracks strict to 1e-10 relative on
/// the GW objective for *every* registered solver, with the iteration
/// schedule unchanged.
#[test]
fn fast_objective_tracks_strict_within_1e10_for_every_solver() {
    let n = 12;
    let mut rng0 = Xoshiro256::new(0xF0);
    let inst = datasets::gaussian::gaussian(n, &mut rng0);
    let p = inst.problem();
    for &name in SolverRegistry::names() {
        let rs = solve_under(name, NumericsPolicy::Strict, &p);
        let rf = solve_under(name, NumericsPolicy::Fast, &p);
        assert!(rs.value.is_finite(), "{name}: strict value not finite");
        assert!(rf.value.is_finite(), "{name}: fast value not finite");
        assert_eq!(
            rs.outer_iters, rf.outer_iters,
            "{name}: fast changed the iteration schedule"
        );
        let rel = (rf.value - rs.value).abs() / rs.value.abs().max(1e-6);
        assert!(
            rel <= 1e-10,
            "{name}: fast {} vs strict {} (rel {rel:e} > 1e-10)",
            rf.value,
            rs.value
        );
        let mass_rel =
            (rf.plan.sum() - rs.plan.sum()).abs() / rs.plan.sum().abs().max(1e-6);
        assert!(
            mass_rel <= 1e-10,
            "{name}: fast plan mass {} vs strict {} (rel {mass_rel:e})",
            rf.plan.sum(),
            rs.plan.sum()
        );
    }
}

/// Within the fast tier: bit-identical across pool widths (the policy
/// is captured at submit time, chunk boundaries and combine order never
/// change) and across repeated runs.
#[test]
fn fast_is_bit_stable_across_thread_widths_and_reruns() {
    let n = 12;
    let mut rng0 = Xoshiro256::new(0xF0);
    let inst = datasets::gaussian::gaussian(n, &mut rng0);
    let p = inst.problem();
    for &name in SolverRegistry::names() {
        let r1 = with_thread_limit(1, || solve_under(name, NumericsPolicy::Fast, &p));
        let r8 = with_thread_limit(8, || solve_under(name, NumericsPolicy::Fast, &p));
        let r8b = with_thread_limit(8, || solve_under(name, NumericsPolicy::Fast, &p));
        assert_eq!(
            r1.value.to_bits(),
            r8.value.to_bits(),
            "{name}: fast value changed across widths ({} vs {})",
            r1.value,
            r8.value
        );
        assert_eq!(
            r1.plan.sum().to_bits(),
            r8.plan.sum().to_bits(),
            "{name}: fast plan mass changed across widths"
        );
        assert_eq!(
            r8.value.to_bits(),
            r8b.value.to_bits(),
            "{name}: fast value changed across reruns at the same width"
        );
    }
}

/// The registry names both tiers for every solver; the SparCore family
/// additionally advertises the fused sweeps.
#[test]
fn registry_reports_numerics_tiers() {
    for &name in SolverRegistry::names() {
        let tiers = SolverRegistry::numerics(name);
        assert!(tiers.contains("strict"), "{name}: {tiers}");
        assert!(tiers.contains("fast"), "{name}: {tiers}");
        assert_eq!(
            tiers.contains("fused sweeps"),
            SolverRegistry::supports_f32(name),
            "{name}: fused-sweep note must track the SparCore family: {tiers}"
        );
    }
}
