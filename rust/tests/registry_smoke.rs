//! Registry smoke tests: every solver constructible through
//! `SolverRegistry` runs end-to-end on a tiny fixed problem (m = n = 12)
//! and returns a sane `SolveReport`; unknown solver names and unknown
//! option keys fail with descriptive errors listing the valid choices.
//! This file is also exercised as a dedicated CI step
//! (`cargo test --release --test registry_smoke`).

use std::collections::BTreeMap;

use spargw::gw::core::Workspace;
use spargw::gw::solver::{PreparedStructure, SolverBase, SolverRegistry};
use spargw::gw::GwProblem;
use spargw::linalg::Mat;
use spargw::rng::Xoshiro256;
use spargw::util::uniform;

const N: usize = 12;
const OUTER_CAP: usize = 8;

fn relation(n: usize, seed: u64) -> Mat {
    let mut rng = Xoshiro256::new(seed);
    let pts: Vec<[f64; 2]> = (0..n).map(|_| [rng.f64(), rng.f64()]).collect();
    Mat::from_fn(n, n, |i, j| spargw::linalg::sqdist(&pts[i], &pts[j]).sqrt())
}

fn smoke_base() -> SolverBase {
    // 300 inner sweeps keep the dense Sinkhorn projections tight on the
    // 12×12 problem, so the marginal checks below are meaningful.
    SolverBase { outer_iters: OUTER_CAP, inner_iters: 300, ..Default::default() }
}

/// Per-solver option overrides for the smoke run (LR-GW's mirror-descent
/// schedule keeps its own defaults, so pin its cap explicitly).
fn smoke_opts(name: &str) -> BTreeMap<String, String> {
    let mut opts = BTreeMap::new();
    if name == "lr_gw" {
        opts.insert("outer".to_string(), OUTER_CAP.to_string());
    }
    opts
}

#[test]
fn every_registered_solver_runs_on_a_tiny_problem() {
    let c1 = relation(N, 1);
    let c2 = relation(N, 2);
    let a = uniform(N);
    let p = GwProblem::new(&c1, &c2, &a, &a);
    let base = smoke_base();

    for &name in SolverRegistry::names() {
        let solver = SolverRegistry::build_with_base(name, &smoke_opts(name), &base)
            .unwrap_or_else(|e| panic!("{name}: build failed: {e}"));
        assert_eq!(solver.name(), name, "registry name round-trip");
        let mut rng = Xoshiro256::new(42);
        let mut ws = Workspace::new();
        let r = solver
            .solve(&p, &mut rng, &mut ws)
            .unwrap_or_else(|e| panic!("{name}: solve failed: {e}"));

        // A finite, non-negative estimate and a finite plan.
        assert!(
            r.value.is_finite() && r.value >= -1e-6,
            "{name}: value {}",
            r.value
        );
        assert!(r.plan.is_finite(), "{name}: non-finite plan entries");
        assert!(r.plan.nnz() > 0, "{name}: empty plan");
        assert!(r.timings.total() >= 0.0, "{name}: negative timings");

        // `converged` is consistent with the iteration cap: nobody
        // exceeds it, and the iterative engines that report
        // non-convergence must have exhausted it (sgwl reports the
        // coarse-level count and never claims convergence; anchor is
        // one-shot exact with outer_iters = 1).
        assert!(
            r.outer_iters <= OUTER_CAP,
            "{name}: outer_iters {} > cap {OUTER_CAP}",
            r.outer_iters
        );
        if r.converged {
            assert!(r.outer_iters >= 1, "{name}: converged with zero iterations");
        } else if name != "sgwl" {
            assert_eq!(
                r.outer_iters, OUTER_CAP,
                "{name}: not converged but stopped before the cap"
            );
        }

        // Balanced solvers transport (approximately) unit mass with the
        // problem marginals; the unbalanced solver only keeps positive
        // finite mass.
        if name == "spar_ugw" {
            assert!(r.plan.sum() > 0.0, "{name}: plan mass {}", r.plan.sum());
            continue;
        }
        let mass = r.plan.sum();
        assert!(
            (mass - 1.0).abs() < 0.1,
            "{name}: plan mass {mass} far from 1"
        );
        // Dense engines project (near-)exactly; sparse plans honor the
        // marginals only on the sampled support (qgw inherits its coarse
        // spar_gw solver's marginal error through the extension).
        let tol = if name.starts_with("spar") || name == "qgw" { 0.5 } else { 0.1 };
        let row_err: f64 =
            r.plan.row_sums().iter().zip(&a).map(|(x, y)| (x - y).abs()).sum();
        let col_err: f64 =
            r.plan.col_sums().iter().zip(&a).map(|(x, y)| (x - y).abs()).sum();
        assert!(row_err < tol, "{name}: row-marginal L1 error {row_err}");
        assert!(col_err < tol, "{name}: col-marginal L1 error {col_err}");
    }
}

/// The solver names whose `supports_fused` must be true (and whose
/// `solve_fused` must run); everyone else must decline with a
/// descriptive error — from BOTH the plain and the prepared entry point.
const FUSED: &[&str] = &["spar_gw", "spar_fgw", "egw", "pga_gw", "emd_gw", "sagrow"];

#[test]
fn every_solver_exercises_solve_fused_or_declines_descriptively() {
    let c1 = relation(N, 3);
    let c2 = relation(N, 4);
    let a = uniform(N);
    let gw = GwProblem::new(&c1, &c2, &a, &a);
    let feat = Mat::full(N, N, 0.5);
    let fp = spargw::gw::fgw::FgwProblem::new(gw, &feat, 0.6);
    let base = smoke_base();

    for &name in SolverRegistry::names() {
        let solver =
            SolverRegistry::build_with_base(name, &smoke_opts(name), &base).unwrap();
        let mut rng = Xoshiro256::new(7);
        let mut ws = Workspace::new();
        if FUSED.contains(&name) {
            assert!(solver.supports_fused(), "{name} should support fused");
            let r = solver.solve_fused(&fp, &mut rng, &mut ws).unwrap();
            assert!(r.value.is_finite(), "{name}: fused value {}", r.value);
            assert!(r.plan.is_finite(), "{name}: non-finite fused plan");
        } else {
            assert!(!solver.supports_fused(), "{name} should be structure-only");
            let err = solver.solve_fused(&fp, &mut rng, &mut ws).unwrap_err();
            let msg = format!("{err}");
            assert!(msg.contains(name), "{msg} should name the solver");
            assert!(msg.contains("fused"), "{msg} should explain the limitation");
        }
    }
}

#[test]
fn prepared_entry_points_match_plain_solves_bit_for_bit() {
    // The prepared entry points are a pure amortization: for every
    // registered solver, identical RNG streams must give bit-identical
    // reports, and structure-only solvers must decline the fused prepared
    // path with the same descriptive error as the plain one (an error,
    // not a panic).
    let c1 = relation(N, 5);
    let c2 = relation(N, 6);
    let a = uniform(N);
    let sx = PreparedStructure::new(a.clone());
    let sy = PreparedStructure::new(a.clone());
    let gw = GwProblem::new(&c1, &c2, &a, &a);
    let feat = Mat::full(N, N, 0.5);
    let fp = spargw::gw::fgw::FgwProblem::new(gw, &feat, 0.6);
    let base = smoke_base();

    for &name in SolverRegistry::names() {
        let solver =
            SolverRegistry::build_with_base(name, &smoke_opts(name), &base).unwrap();

        let mut rng1 = Xoshiro256::new(42);
        let mut ws1 = Workspace::new();
        let plain = solver
            .solve(&gw, &mut rng1, &mut ws1)
            .unwrap_or_else(|e| panic!("{name}: solve failed: {e}"));
        let mut rng2 = Xoshiro256::new(42);
        let mut ws2 = Workspace::new();
        let prepared = solver
            .solve_prepared(&gw, &sx, &sy, &mut rng2, &mut ws2)
            .unwrap_or_else(|e| panic!("{name}: solve_prepared failed: {e}"));
        assert_eq!(
            plain.value.to_bits(),
            prepared.value.to_bits(),
            "{name}: prepared value differs ({} vs {})",
            plain.value,
            prepared.value
        );
        assert_eq!(plain.outer_iters, prepared.outer_iters, "{name}: outer iters");
        assert_eq!(plain.converged, prepared.converged, "{name}: converged flag");

        let mut rngf1 = Xoshiro256::new(43);
        let mut rngf2 = Xoshiro256::new(43);
        let mut wsf1 = Workspace::new();
        let mut wsf2 = Workspace::new();
        if FUSED.contains(&name) {
            let f_plain = solver.solve_fused(&fp, &mut rngf1, &mut wsf1).unwrap();
            let f_prep = solver
                .solve_fused_prepared(&fp, &sx, &sy, &mut rngf2, &mut wsf2)
                .unwrap_or_else(|e| panic!("{name}: solve_fused_prepared failed: {e}"));
            assert_eq!(
                f_plain.value.to_bits(),
                f_prep.value.to_bits(),
                "{name}: fused prepared value differs"
            );
        } else {
            let err = solver
                .solve_fused_prepared(&fp, &sx, &sy, &mut rngf2, &mut wsf2)
                .unwrap_err();
            let msg = format!("{err}");
            assert!(msg.contains(name), "{msg} should name the solver");
            assert!(msg.contains("fused"), "{msg} should explain the limitation");
        }
    }
}

#[test]
fn unknown_solver_name_lists_valid_choices() {
    let err = SolverRegistry::build("warp_drive", &BTreeMap::new()).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("unknown solver"), "{msg}");
    assert!(msg.contains("warp_drive"), "{msg}");
    for &name in SolverRegistry::names() {
        assert!(msg.contains(name), "{msg} missing valid choice {name}");
    }
}

#[test]
fn unknown_solver_opt_key_lists_valid_keys() {
    for &name in SolverRegistry::names() {
        let mut opts = BTreeMap::new();
        opts.insert("definitely_not_a_key".to_string(), "1".to_string());
        let err = SolverRegistry::build(name, &opts).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("definitely_not_a_key"), "{name}: {msg}");
        assert!(msg.contains("valid keys"), "{name}: {msg}");
        assert!(msg.contains("cost"), "{name}: {msg} should list the cost key");
    }
}

#[test]
fn lr_gw_declines_l1_with_an_error_not_a_panic() {
    let c1 = relation(N, 5);
    let c2 = relation(N, 6);
    let a = uniform(N);
    let p = GwProblem::new(&c1, &c2, &a, &a);
    let mut opts = smoke_opts("lr_gw");
    opts.insert("cost".to_string(), "l1".to_string());
    let solver = SolverRegistry::build_with_base("lr_gw", &opts, &smoke_base()).unwrap();
    let mut rng = Xoshiro256::new(8);
    let mut ws = Workspace::new();
    let err = solver.solve(&p, &mut rng, &mut ws).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("decomposable"), "{msg}");
    assert!(msg.contains("l1"), "{msg}");
}
