//! Cross-module integration tests over the full solver family: every
//! method on shared instances, degeneracy relations between the GW
//! variants, and agreement between sparse and dense paths.

use spargw::bench::workloads::{attach_features, Workload};
use spargw::bench::{Method, RunSettings};
use spargw::gw::fgw::{naive_fgw, pga_fgw, FgwProblem};
use spargw::gw::spar_gw::{spar_gw, SparGwConfig};
use spargw::gw::spar_ugw::{spar_ugw, SparUgwConfig};
use spargw::gw::ugw::{pga_ugw, UgwConfig};
use spargw::gw::{pga_gw, Alg1Config, GroundCost, GwProblem};
use spargw::rng::Xoshiro256;
use spargw::testutil::assert_close;
use spargw::util::{mean, uniform};

#[test]
fn all_methods_agree_on_identical_spaces() {
    // GW((C, a), (C, a)) = 0: every solver should land near zero (AE and
    // sampled methods within a loose tolerance).
    let mut rng = Xoshiro256::new(1);
    let inst = Workload::Moon.make(24, &mut rng);
    let p = GwProblem::new(&inst.cx, &inst.cx, &inst.a, &inst.a);
    let st = RunSettings { outer_iters: 25, inner_iters: 50, ..Default::default() };
    for &m in Method::all() {
        if m == Method::Naive {
            continue; // the naive plan is not optimal by construction
        }
        let out = m.run(&p, None, GroundCost::L2, &st, &mut rng).unwrap();
        assert!(
            out.value.abs() < 0.05,
            "{} on identical spaces: {}",
            m.name(),
            out.value
        );
    }
}

#[test]
fn every_method_beats_or_matches_naive() {
    let mut rng = Xoshiro256::new(2);
    let inst = Workload::Moon.make(30, &mut rng);
    let p = inst.problem();
    let st = RunSettings { outer_iters: 20, ..Default::default() };
    let naive = Method::Naive.run(&p, None, GroundCost::L2, &st, &mut rng).unwrap().value;
    for &m in Method::all() {
        let out = m.run(&p, None, GroundCost::L2, &st, &mut rng).unwrap();
        assert!(
            out.value <= naive * 1.10 + 1e-9,
            "{}: {} vs naive {}",
            m.name(),
            out.value,
            naive
        );
    }
}

#[test]
fn spar_gw_tracks_dense_benchmark_on_all_workloads() {
    for (wi, &w) in Workload::all().iter().enumerate() {
        let mut rng = Xoshiro256::new(100 + wi as u64);
        let inst = w.make(40, &mut rng);
        let p = inst.problem();
        let dense = pga_gw(&p, GroundCost::L2, &Alg1Config::default()).value;
        let cfg = SparGwConfig { sample_size: 32 * 40, ..Default::default() };
        let vals: Vec<f64> =
            (0..3).map(|_| spar_gw(&p, GroundCost::L2, &cfg, &mut rng).value).collect();
        let est = mean(&vals);
        // Same order of magnitude + finite (the paper's Fig. 2 claim at
        // this budget); both can be near zero on easy instances.
        assert!(est.is_finite() && est >= -1e-9, "{}: {est}", w.name());
        assert!(
            (est - dense).abs() <= 0.5 * dense.abs().max(0.05),
            "{}: spar {est} vs dense {dense}",
            w.name()
        );
    }
}

#[test]
fn fgw_alpha_one_equals_gw_and_alpha_zero_equals_w() {
    let mut rng = Xoshiro256::new(4);
    let mut inst = Workload::Moon.make(20, &mut rng);
    attach_features(&mut inst, &mut rng);
    let p = inst.problem();
    let feat = inst.feat.as_ref().unwrap();
    let cfg = Alg1Config::default();

    // α = 1: fused objective equals plain GW.
    let fp1 = FgwProblem::new(p, feat, 1.0);
    let gw = pga_gw(&p, GroundCost::L2, &cfg).value;
    let fgw1 = pga_fgw(&fp1, GroundCost::L2, &cfg).value;
    assert_close(fgw1, gw, 1e-6, 1e-9, "FGW(α=1) vs GW");

    // α = 0: the structural term vanishes; the objective is ⟨M, T⟩,
    // minimized by the entropic OT plan — upper-bounded by the naive plan.
    let fp0 = FgwProblem::new(p, feat, 0.0);
    let w = pga_fgw(&fp0, GroundCost::L2, &cfg).value;
    let naive_w = naive_fgw(&fp0, GroundCost::L2);
    assert!(w <= naive_w + 1e-9, "W {w} vs naive ⟨M, abᵀ⟩ {naive_w}");
}

#[test]
fn ugw_with_balanced_masses_and_large_lambda_approaches_gw() {
    // §5.1: as λ → ∞ with unit masses, UGW degenerates to GW.
    let mut rng = Xoshiro256::new(5);
    let inst = Workload::Moon.make(20, &mut rng);
    let p = inst.problem();
    let gw = pga_gw(&p, GroundCost::L2, &Alg1Config::default()).value;
    let cfg = UgwConfig { lambda: 1e4, ..Default::default() };
    let u = pga_ugw(&p, GroundCost::L2, &cfg);
    // The KL penalty pins the marginals: quadratic part ≈ GW.
    let quad = {
        use spargw::gw::tensor::gw_energy;
        gw_energy(p.cx, p.cy, &u.plan, GroundCost::L2)
    };
    assert_close(quad, gw, 0.25, 5e-3, "UGW(λ→∞) quadratic vs GW");
    // Marginal defect is tiny.
    let r = u.plan.row_sums();
    let defect: f64 =
        r.iter().zip(p.a).map(|(x, y)| (x - y).abs()).sum::<f64>() / p.a.len() as f64;
    assert!(defect < 1e-3, "marginal defect {defect}");
}

#[test]
fn spar_ugw_degenerates_to_spar_gw_shape() {
    // m(a) = m(b) = 1 and large λ: Spar-UGW ≈ Spar-GW on the same set.
    let n = 24;
    let mut rng = Xoshiro256::new(6);
    let inst = Workload::Moon.make(n, &mut rng);
    let p = inst.problem();
    let ucfg = SparUgwConfig {
        ugw: UgwConfig { lambda: 1e4, ..Default::default() },
        sample_size: 32 * n,
        shrink: 0.0,
    };
    let u = spar_ugw(&p, GroundCost::L2, &ucfg, &mut rng);
    let gcfg = SparGwConfig { sample_size: 32 * n, ..Default::default() };
    let g = spar_gw(&p, GroundCost::L2, &gcfg, &mut rng);
    assert!(u.value.is_finite() && g.value.is_finite());
    // Total plan masses agree (≈ 1).
    assert_close(u.plan.sum(), 1.0, 0.05, 0.0, "Spar-UGW plan mass");
    assert_close(g.plan.sum(), 1.0, 0.05, 0.0, "Spar-GW plan mass");
}

#[test]
fn l1_and_l2_costs_rank_workload_pairs_consistently() {
    // Two different workloads: the (Moon, Moon-copy) pair must be closer
    // than (Moon, Graph) under every cost for the dense benchmark.
    let n = 24;
    let mut rng = Xoshiro256::new(7);
    let a_inst = Workload::Moon.make(n, &mut rng);
    let b_inst = Workload::Graph.make(n, &mut rng);
    let cfg = Alg1Config::default();
    for cost in [GroundCost::L1, GroundCost::L2] {
        let near = pga_gw(
            &GwProblem::new(&a_inst.cx, &a_inst.cx, &a_inst.a, &a_inst.a),
            cost,
            &cfg,
        )
        .value;
        let far = pga_gw(
            &GwProblem::new(&a_inst.cx, &b_inst.cy, &a_inst.a, &b_inst.b),
            cost,
            &cfg,
        )
        .value;
        assert!(near < far, "{}: near {near} !< far {far}", cost.name());
    }
}

#[test]
fn uniform_marginal_problem_is_symmetric() {
    // GW((Cx,a),(Cy,b)) = GW((Cy,b),(Cx,a)) for the dense solver.
    let n = 18;
    let mut rng = Xoshiro256::new(8);
    let inst = Workload::Gaussian.make(n, &mut rng);
    let a = uniform(n);
    let fwd = pga_gw(
        &GwProblem::new(&inst.cx, &inst.cy, &a, &a),
        GroundCost::L2,
        &Alg1Config::default(),
    )
    .value;
    let bwd = pga_gw(
        &GwProblem::new(&inst.cy, &inst.cx, &a, &a),
        GroundCost::L2,
        &Alg1Config::default(),
    )
    .value;
    // The alternating scheme is not exactly exchange-symmetric (Sinkhorn
    // updates u before v), so allow a small relative slack.
    assert_close(fwd, bwd, 1e-2, 1e-9, "GW symmetry");
}
