//! Cross-module integration tests over the full solver family: every
//! method on shared instances, degeneracy relations between the GW
//! variants, and agreement between sparse and dense paths.

use spargw::bench::workloads::{attach_features, Workload};
use spargw::bench::{Method, RunSettings};
use spargw::gw::fgw::{naive_fgw, pga_fgw, FgwProblem};
use spargw::gw::spar_gw::{spar_gw, SparGwConfig};
use spargw::gw::spar_ugw::{spar_ugw, SparUgwConfig};
use spargw::gw::ugw::{pga_ugw, UgwConfig};
use spargw::gw::{pga_gw, Alg1Config, GroundCost, GwProblem};
use spargw::rng::Xoshiro256;
use spargw::testutil::assert_close;
use spargw::util::{mean, uniform};

#[test]
fn all_methods_agree_on_identical_spaces() {
    // GW((C, a), (C, a)) = 0: every solver should land near zero (AE and
    // sampled methods within a loose tolerance).
    let mut rng = Xoshiro256::new(1);
    let inst = Workload::Moon.make(24, &mut rng);
    let p = GwProblem::new(&inst.cx, &inst.cx, &inst.a, &inst.a);
    let st = RunSettings { outer_iters: 25, inner_iters: 50, ..Default::default() };
    for &m in Method::all() {
        if m == Method::Naive {
            continue; // the naive plan is not optimal by construction
        }
        let out = m.run(&p, None, GroundCost::L2, &st, &mut rng).unwrap();
        assert!(
            out.value.abs() < 0.05,
            "{} on identical spaces: {}",
            m.name(),
            out.value
        );
    }
}

#[test]
fn every_method_beats_or_matches_naive() {
    let mut rng = Xoshiro256::new(2);
    let inst = Workload::Moon.make(30, &mut rng);
    let p = inst.problem();
    let st = RunSettings { outer_iters: 20, ..Default::default() };
    let naive = Method::Naive.run(&p, None, GroundCost::L2, &st, &mut rng).unwrap().value;
    for &m in Method::all() {
        let out = m.run(&p, None, GroundCost::L2, &st, &mut rng).unwrap();
        assert!(
            out.value <= naive * 1.10 + 1e-9,
            "{}: {} vs naive {}",
            m.name(),
            out.value,
            naive
        );
    }
}

#[test]
fn spar_gw_tracks_dense_benchmark_on_all_workloads() {
    for (wi, &w) in Workload::all().iter().enumerate() {
        let mut rng = Xoshiro256::new(100 + wi as u64);
        let inst = w.make(40, &mut rng);
        let p = inst.problem();
        let dense = pga_gw(&p, GroundCost::L2, &Alg1Config::default()).value;
        let cfg = SparGwConfig { sample_size: 32 * 40, ..Default::default() };
        let vals: Vec<f64> =
            (0..3).map(|_| spar_gw(&p, GroundCost::L2, &cfg, &mut rng).value).collect();
        let est = mean(&vals);
        // Same order of magnitude + finite (the paper's Fig. 2 claim at
        // this budget); both can be near zero on easy instances.
        assert!(est.is_finite() && est >= -1e-9, "{}: {est}", w.name());
        assert!(
            (est - dense).abs() <= 0.5 * dense.abs().max(0.05),
            "{}: spar {est} vs dense {dense}",
            w.name()
        );
    }
}

#[test]
fn fgw_alpha_one_equals_gw_and_alpha_zero_equals_w() {
    let mut rng = Xoshiro256::new(4);
    let mut inst = Workload::Moon.make(20, &mut rng);
    attach_features(&mut inst, &mut rng);
    let p = inst.problem();
    let feat = inst.feat.as_ref().unwrap();
    let cfg = Alg1Config::default();

    // α = 1: fused objective equals plain GW.
    let fp1 = FgwProblem::new(p, feat, 1.0);
    let gw = pga_gw(&p, GroundCost::L2, &cfg).value;
    let fgw1 = pga_fgw(&fp1, GroundCost::L2, &cfg).value;
    assert_close(fgw1, gw, 1e-6, 1e-9, "FGW(α=1) vs GW");

    // α = 0: the structural term vanishes; the objective is ⟨M, T⟩,
    // minimized by the entropic OT plan — upper-bounded by the naive plan.
    let fp0 = FgwProblem::new(p, feat, 0.0);
    let w = pga_fgw(&fp0, GroundCost::L2, &cfg).value;
    let naive_w = naive_fgw(&fp0, GroundCost::L2);
    assert!(w <= naive_w + 1e-9, "W {w} vs naive ⟨M, abᵀ⟩ {naive_w}");
}

#[test]
fn ugw_with_balanced_masses_and_large_lambda_approaches_gw() {
    // §5.1: as λ → ∞ with unit masses, UGW degenerates to GW.
    let mut rng = Xoshiro256::new(5);
    let inst = Workload::Moon.make(20, &mut rng);
    let p = inst.problem();
    let gw = pga_gw(&p, GroundCost::L2, &Alg1Config::default()).value;
    let cfg = UgwConfig { lambda: 1e4, ..Default::default() };
    let u = pga_ugw(&p, GroundCost::L2, &cfg);
    // The KL penalty pins the marginals: quadratic part ≈ GW.
    let quad = {
        use spargw::gw::tensor::gw_energy;
        gw_energy(p.cx, p.cy, &u.plan, GroundCost::L2)
    };
    assert_close(quad, gw, 0.25, 5e-3, "UGW(λ→∞) quadratic vs GW");
    // Marginal defect is tiny.
    let r = u.plan.row_sums();
    let defect: f64 =
        r.iter().zip(p.a).map(|(x, y)| (x - y).abs()).sum::<f64>() / p.a.len() as f64;
    assert!(defect < 1e-3, "marginal defect {defect}");
}

#[test]
fn spar_ugw_degenerates_to_spar_gw_shape() {
    // m(a) = m(b) = 1 and large λ: Spar-UGW ≈ Spar-GW on the same set.
    let n = 24;
    let mut rng = Xoshiro256::new(6);
    let inst = Workload::Moon.make(n, &mut rng);
    let p = inst.problem();
    let ucfg = SparUgwConfig {
        ugw: UgwConfig { lambda: 1e4, ..Default::default() },
        sample_size: 32 * n,
        shrink: 0.0,
    };
    let u = spar_ugw(&p, GroundCost::L2, &ucfg, &mut rng);
    let gcfg = SparGwConfig { sample_size: 32 * n, ..Default::default() };
    let g = spar_gw(&p, GroundCost::L2, &gcfg, &mut rng);
    assert!(u.value.is_finite() && g.value.is_finite());
    // Total plan masses agree (≈ 1).
    assert_close(u.plan.sum(), 1.0, 0.05, 0.0, "Spar-UGW plan mass");
    assert_close(g.plan.sum(), 1.0, 0.05, 0.0, "Spar-GW plan mass");
}

#[test]
fn l1_and_l2_costs_rank_workload_pairs_consistently() {
    // Two different workloads: the (Moon, Moon-copy) pair must be closer
    // than (Moon, Graph) under every cost for the dense benchmark.
    let n = 24;
    let mut rng = Xoshiro256::new(7);
    let a_inst = Workload::Moon.make(n, &mut rng);
    let b_inst = Workload::Graph.make(n, &mut rng);
    let cfg = Alg1Config::default();
    for cost in [GroundCost::L1, GroundCost::L2] {
        let near = pga_gw(
            &GwProblem::new(&a_inst.cx, &a_inst.cx, &a_inst.a, &a_inst.a),
            cost,
            &cfg,
        )
        .value;
        let far = pga_gw(
            &GwProblem::new(&a_inst.cx, &b_inst.cy, &a_inst.a, &b_inst.b),
            cost,
            &cfg,
        )
        .value;
        assert!(near < far, "{}: near {near} !< far {far}", cost.name());
    }
}

/// Golden lock for the SparCore refactor: the pre-refactor Spar-GW /
/// Spar-FGW / Spar-UGW loops, ported verbatim (same operations in the
/// same order) from the standalone implementations this repository
/// shipped before the solvers became adapters over `gw::core`. The tests
/// below assert the refactored solvers are **bit-identical** to these
/// references on fixed seeds — value, plan entries, iteration counts and
/// convergence flags all compared via `f64::to_bits`.
mod golden {
    use spargw::gw::fgw::FgwProblem;
    use spargw::gw::sampling::SampledSet;
    use spargw::gw::spar_gw::SparGwConfig;
    use spargw::gw::spar_ugw::SparUgwConfig;
    use spargw::gw::tensor::SparseCostContext;
    use spargw::gw::ugw::{kl_otimes, unbalanced_cost_shift};
    use spargw::gw::{GroundCost, GwProblem, Regularizer};
    use spargw::ot::{sparse_sinkhorn, sparse_unbalanced_sinkhorn};
    use spargw::sparse::Coo;

    pub struct RefResult {
        pub value: f64,
        pub plan_vals: Vec<f64>,
        pub outer_iters: usize,
        pub converged: bool,
    }

    /// Pre-refactor Algorithm 2 (balanced Spar-GW) on a fixed set.
    pub fn spar_gw_ref(
        p: &GwProblem,
        cost: GroundCost,
        cfg: &SparGwConfig,
        set: &SampledSet,
    ) -> RefResult {
        let (m, n) = (p.m(), p.n());
        let s = set.len();
        let ctx = SparseCostContext::new(p.cx, p.cy, &set.rows, &set.cols, cost);
        let mut t_vals: Vec<f64> =
            set.rows.iter().zip(&set.cols).map(|(&i, &j)| p.a[i] * p.b[j]).collect();
        let inv_w: Vec<f64> = set.weights.iter().map(|&w| 1.0 / w).collect();
        let mut outer = 0;
        let mut converged = false;
        let mut k_vals = vec![0.0f64; s];
        let mut c_red = vec![0.0f64; s];
        for _r in 0..cfg.outer_iters {
            let c_vals = ctx.cost_values(&t_vals);
            let mut row_min = vec![f64::INFINITY; m];
            for l in 0..s {
                let i = set.rows[l];
                if c_vals[l] < row_min[i] {
                    row_min[i] = c_vals[l];
                }
            }
            let mut col_min = vec![f64::INFINITY; n];
            for l in 0..s {
                let v = c_vals[l] - row_min[set.rows[l]];
                let j = set.cols[l];
                if v < col_min[j] {
                    col_min[j] = v;
                }
            }
            for l in 0..s {
                c_red[l] = c_vals[l] - row_min[set.rows[l]] - col_min[set.cols[l]];
            }
            match cfg.reg {
                Regularizer::Proximal => {
                    for l in 0..s {
                        k_vals[l] = if c_vals[l] == 0.0 && t_vals[l] == 0.0 {
                            0.0
                        } else {
                            (-c_red[l] / cfg.epsilon).exp() * t_vals[l] * inv_w[l]
                        };
                    }
                }
                Regularizer::Entropy => {
                    for l in 0..s {
                        k_vals[l] = (-c_red[l] / cfg.epsilon).exp() * inv_w[l];
                    }
                }
            }
            let k = Coo::from_triplets(m, n, &set.rows, &set.cols, &k_vals);
            let (plan, _) = sparse_sinkhorn(p.a, p.b, &k, cfg.inner_iters, 0.0);
            let new_vals = plan.vals().to_vec();
            if !new_vals.iter().all(|v| v.is_finite()) {
                break;
            }
            outer += 1;
            if cfg.tol > 0.0 {
                let mut diff = 0.0;
                for (x, y) in new_vals.iter().zip(&t_vals) {
                    let d = x - y;
                    diff += d * d;
                }
                if diff.sqrt() < cfg.tol {
                    t_vals = new_vals;
                    converged = true;
                    break;
                }
            }
            t_vals = new_vals;
        }
        let value = ctx.energy(&t_vals);
        RefResult { value, plan_vals: t_vals, outer_iters: outer, converged }
    }

    /// Pre-refactor Algorithm 4 (fused Spar-FGW) on a fixed set.
    pub fn spar_fgw_ref(
        p: &FgwProblem,
        cost: GroundCost,
        cfg: &SparGwConfig,
        set: &SampledSet,
    ) -> RefResult {
        let (m, n) = (p.gw.m(), p.gw.n());
        let s = set.len();
        let alpha = p.alpha;
        let ctx = SparseCostContext::new(p.gw.cx, p.gw.cy, &set.rows, &set.cols, cost);
        let m_vals: Vec<f64> =
            set.rows.iter().zip(&set.cols).map(|(&i, &j)| p.feat[(i, j)]).collect();
        let mut t_vals: Vec<f64> =
            set.rows.iter().zip(&set.cols).map(|(&i, &j)| p.gw.a[i] * p.gw.b[j]).collect();
        let inv_w: Vec<f64> = set.weights.iter().map(|&w| 1.0 / w).collect();
        let mut outer = 0;
        let mut converged = false;
        let mut k_vals = vec![0.0f64; s];
        let mut c_fu = vec![0.0f64; s];
        for _ in 0..cfg.outer_iters {
            let c_gw = ctx.cost_values(&t_vals);
            for l in 0..s {
                c_fu[l] = alpha * c_gw[l] + (1.0 - alpha) * m_vals[l];
            }
            let mut row_min = vec![f64::INFINITY; m];
            for l in 0..s {
                let i = set.rows[l];
                if c_fu[l] < row_min[i] {
                    row_min[i] = c_fu[l];
                }
            }
            let mut col_min = vec![f64::INFINITY; n];
            for l in 0..s {
                let v = c_fu[l] - row_min[set.rows[l]];
                let j = set.cols[l];
                if v < col_min[j] {
                    col_min[j] = v;
                }
            }
            for l in 0..s {
                let c_red = c_fu[l] - row_min[set.rows[l]] - col_min[set.cols[l]];
                let e = (-c_red / cfg.epsilon).exp();
                k_vals[l] = match cfg.reg {
                    Regularizer::Proximal => e * t_vals[l] * inv_w[l],
                    Regularizer::Entropy => e * inv_w[l],
                };
            }
            let k = Coo::from_triplets(m, n, &set.rows, &set.cols, &k_vals);
            let (plan, _) = sparse_sinkhorn(p.gw.a, p.gw.b, &k, cfg.inner_iters, 0.0);
            let new_vals = plan.vals().to_vec();
            outer += 1;
            if cfg.tol > 0.0 {
                let mut diff = 0.0;
                for (x, y) in new_vals.iter().zip(&t_vals) {
                    let d = x - y;
                    diff += d * d;
                }
                if diff.sqrt() < cfg.tol {
                    t_vals = new_vals;
                    converged = true;
                    break;
                }
            }
            t_vals = new_vals;
        }
        let gw_term = ctx.energy(&t_vals);
        let w_term: f64 = m_vals.iter().zip(&t_vals).map(|(m, t)| m * t).sum();
        let value = alpha * gw_term + (1.0 - alpha) * w_term;
        RefResult { value, plan_vals: t_vals, outer_iters: outer, converged }
    }

    /// Pre-refactor Algorithm 3 (unbalanced Spar-UGW) on a fixed set.
    pub fn spar_ugw_ref(
        p: &GwProblem,
        cost: GroundCost,
        cfg: &SparUgwConfig,
        set: &SampledSet,
    ) -> RefResult {
        let (m, n) = (p.m(), p.n());
        let s = set.len();
        let lam = cfg.ugw.lambda;
        let ma: f64 = p.a.iter().sum();
        let mb: f64 = p.b.iter().sum();
        let ctx = SparseCostContext::new(p.cx, p.cy, &set.rows, &set.cols, cost);
        let norm0 = 1.0 / (ma * mb).sqrt();
        let mut t = Coo::with_pattern(m, n, &set.rows, &set.cols);
        for (l, (&i, &j)) in set.rows.iter().zip(&set.cols).enumerate() {
            t.vals_mut()[l] = p.a[i] * p.b[j] * norm0;
        }
        let inv_w: Vec<f64> = set.weights.iter().map(|&w| 1.0 / w).collect();
        let mut outer = 0;
        let mut k_vals = vec![0.0f64; s];
        for _ in 0..cfg.ugw.outer_iters {
            let mass = t.sum();
            if mass <= 0.0 || !mass.is_finite() {
                break;
            }
            let eps_bar = cfg.ugw.epsilon * mass;
            let lam_bar = lam * mass;
            let c_vals = ctx.cost_values(t.vals());
            let shift = unbalanced_cost_shift(&t.row_sums(), &t.col_sums(), p.a, p.b, lam);
            for l in 0..s {
                k_vals[l] = (-(c_vals[l] + shift) / eps_bar).exp() * t.vals()[l] * inv_w[l];
            }
            let k = Coo::from_triplets(m, n, &set.rows, &set.cols, &k_vals);
            let mut t_next =
                sparse_unbalanced_sinkhorn(p.a, p.b, &k, lam_bar, eps_bar, cfg.ugw.inner_iters);
            let next_mass = t_next.sum();
            if !next_mass.is_finite() || next_mass <= 0.0 {
                break;
            }
            let scale = (mass / next_mass).sqrt();
            t_next.map_inplace(|v| v * scale);
            outer += 1;
            if cfg.ugw.tol > 0.0 {
                let diff = t.pattern_sqdist(&t_next).sqrt();
                t = t_next;
                if diff < cfg.ugw.tol {
                    break;
                }
            } else {
                t = t_next;
            }
        }
        let quad = ctx.energy(t.vals());
        let r = t.row_sums();
        let c = t.col_sums();
        let value = quad + lam * kl_otimes(&r, p.a) + lam * kl_otimes(&c, p.b);
        RefResult { value, plan_vals: t.vals().to_vec(), outer_iters: outer, converged: false }
    }
}

fn assert_bits_eq(label: &str, new_vals: &[f64], ref_vals: &[f64]) {
    assert_eq!(new_vals.len(), ref_vals.len(), "{label}: length mismatch");
    for (l, (&x, &y)) in new_vals.iter().zip(ref_vals).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{label}: entry {l} differs ({x:e} vs {y:e})"
        );
    }
}

#[test]
fn spar_gw_bit_identical_to_pre_refactor_reference() {
    use spargw::gw::sampling::GwSampler;
    use spargw::gw::spar_gw::spar_gw_with_set;
    use spargw::gw::Regularizer;

    // Sweep regularizers, costs, tolerances, shrinkage and marginal
    // shapes; every cell must reproduce the historical trajectory bit-
    // for-bit, including iteration counts and the convergence flag.
    let n = 21;
    let mut rng = Xoshiro256::new(301);
    let inst = Workload::Moon.make(n, &mut rng);
    let mut a_nonunif: Vec<f64> = (0..n).map(|i| 1.0 + (i % 5) as f64).collect();
    spargw::util::normalize(&mut a_nonunif);
    let b = uniform(n);

    let cases: Vec<(Regularizer, GroundCost, f64, f64, bool)> = vec![
        (Regularizer::Proximal, GroundCost::L2, 1e-9, 0.0, false),
        (Regularizer::Proximal, GroundCost::L1, 0.0, 0.0, false),
        (Regularizer::Entropy, GroundCost::L2, 1e-9, 0.0, false),
        (Regularizer::Entropy, GroundCost::L1, 1e-3, 0.1, false),
        (Regularizer::Proximal, GroundCost::L2, 1e-9, 0.2, true),
    ];
    for (ci, (reg, cost, tol, shrink, nonunif)) in cases.into_iter().enumerate() {
        let a: &[f64] = if nonunif { &a_nonunif } else { &inst.a };
        let p = GwProblem::new(&inst.cx, &inst.cy, a, &b);
        let mut srng = Xoshiro256::new(400 + ci as u64);
        let sampler = GwSampler::new(a, &b, shrink);
        let set = sampler.sample_iid(&mut srng, 12 * n);
        let cfg = spargw::gw::spar_gw::SparGwConfig {
            sample_size: 12 * n,
            outer_iters: 12,
            inner_iters: 25,
            reg,
            shrink,
            tol,
            ..Default::default()
        };
        let new = spar_gw_with_set(&p, cost, &cfg, &set);
        let golden = golden::spar_gw_ref(&p, cost, &cfg, &set);
        assert_eq!(
            new.value.to_bits(),
            golden.value.to_bits(),
            "case {ci}: value {:e} vs golden {:e}",
            new.value,
            golden.value
        );
        assert_eq!(new.outer_iters, golden.outer_iters, "case {ci}: outer_iters");
        assert_eq!(new.converged, golden.converged, "case {ci}: converged");
        assert_bits_eq(&format!("spar_gw case {ci}"), new.plan.vals(), &golden.plan_vals);
    }
}

#[test]
fn spar_fgw_bit_identical_to_pre_refactor_reference() {
    use spargw::gw::fgw::FgwProblem;
    use spargw::gw::sampling::GwSampler;
    use spargw::gw::spar_fgw::spar_fgw_with_set;
    use spargw::gw::Regularizer;

    let n = 18;
    let mut rng = Xoshiro256::new(501);
    let mut inst = Workload::Graph.make(n, &mut rng);
    attach_features(&mut inst, &mut rng);
    let feat = inst.feat.as_ref().unwrap();
    let gw = inst.problem();

    for (ci, (alpha, reg)) in [
        (0.6, Regularizer::Proximal),
        (1.0, Regularizer::Proximal),
        (0.3, Regularizer::Entropy),
    ]
    .into_iter()
    .enumerate()
    {
        let p = FgwProblem::new(gw, feat, alpha);
        let mut srng = Xoshiro256::new(600 + ci as u64);
        let sampler = GwSampler::new(gw.a, gw.b, 0.0);
        let set = sampler.sample_iid(&mut srng, 10 * n);
        let cfg = spargw::gw::spar_gw::SparGwConfig {
            sample_size: 10 * n,
            outer_iters: 10,
            inner_iters: 20,
            reg,
            ..Default::default()
        };
        let new = spar_fgw_with_set(&p, GroundCost::L2, &cfg, &set);
        let golden = golden::spar_fgw_ref(&p, GroundCost::L2, &cfg, &set);
        assert_eq!(
            new.value.to_bits(),
            golden.value.to_bits(),
            "case {ci}: value {:e} vs golden {:e}",
            new.value,
            golden.value
        );
        assert_eq!(new.outer_iters, golden.outer_iters, "case {ci}: outer_iters");
        assert_eq!(new.converged, golden.converged, "case {ci}: converged");
        assert_bits_eq(&format!("spar_fgw case {ci}"), new.plan.vals(), &golden.plan_vals);
    }
}

#[test]
fn spar_ugw_bit_identical_to_pre_refactor_reference() {
    use spargw::gw::spar_ugw::{sample_ugw_set, spar_ugw_with_set};

    let n = 16;
    let mut rng = Xoshiro256::new(701);
    let inst = Workload::Moon.make(n, &mut rng);
    let a = uniform(n);
    let b_heavy: Vec<f64> = vec![2.0 / n as f64; n]; // mass 2: unbalanced

    for (ci, (b, lambda, tol)) in [
        (&a, 1.0, 1e-9),
        (&b_heavy, 1.0, 1e-9),
        (&a, 0.3, 0.0),
    ]
    .into_iter()
    .enumerate()
    {
        let p = GwProblem::new(&inst.cx, &inst.cy, &a, b);
        let cfg = SparUgwConfig {
            ugw: spargw::gw::ugw::UgwConfig {
                lambda,
                outer_iters: 10,
                inner_iters: 20,
                tol,
                ..Default::default()
            },
            sample_size: 10 * n,
            shrink: 0.1,
        };
        let mut srng = Xoshiro256::new(800 + ci as u64);
        let set = sample_ugw_set(&p, GroundCost::L2, &cfg, &mut srng);
        let new = spar_ugw_with_set(&p, GroundCost::L2, &cfg, &set);
        let golden = golden::spar_ugw_ref(&p, GroundCost::L2, &cfg, &set);
        assert_eq!(
            new.value.to_bits(),
            golden.value.to_bits(),
            "case {ci}: value {:e} vs golden {:e}",
            new.value,
            golden.value
        );
        assert_eq!(new.outer_iters, golden.outer_iters, "case {ci}: outer_iters");
        assert_bits_eq(&format!("spar_ugw case {ci}"), new.plan.vals(), &golden.plan_vals);
    }
}

#[test]
fn uniform_marginal_problem_is_symmetric() {
    // GW((Cx,a),(Cy,b)) = GW((Cy,b),(Cx,a)) for the dense solver.
    let n = 18;
    let mut rng = Xoshiro256::new(8);
    let inst = Workload::Gaussian.make(n, &mut rng);
    let a = uniform(n);
    let fwd = pga_gw(
        &GwProblem::new(&inst.cx, &inst.cy, &a, &a),
        GroundCost::L2,
        &Alg1Config::default(),
    )
    .value;
    let bwd = pga_gw(
        &GwProblem::new(&inst.cy, &inst.cx, &a, &a),
        GroundCost::L2,
        &Alg1Config::default(),
    )
    .value;
    // The alternating scheme is not exactly exchange-symmetric (Sinkhorn
    // updates u before v), so allow a small relative slack.
    assert_close(fwd, bwd, 1e-2, 1e-9, "GW symmetry");
}
