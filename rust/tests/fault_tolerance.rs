//! Fault-tolerance integration suite: the claim protocol end to end
//! (multi-worker handoff, crash recovery via lease reclamation, abort of
//! a live worker process), plus a deterministic fault-injection matrix
//! over every sink/lock/claim IO point. The acceptance bar everywhere is
//! **bit-identity**: whatever faults were injected, the recovered Gram
//! matrix must carry exactly the `f64::to_bits` a clean single-process
//! run produces. No assertion depends on wall-clock time — faults fire
//! on deterministic hit counts and leases are forced with `lease_ms: 0`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use spargw::coordinator::claims::ClaimConfig;
use spargw::coordinator::engine::{EngineConfig, GramResult, PairwiseEngine};
use spargw::coordinator::service::PairwiseConfig;
use spargw::datasets::graphsets::{self, imdb_b, GraphDataset};
use spargw::gw::spar_gw::SparGwConfig;
use spargw::util::fault;

const SEED: u64 = 17;
/// 6 graphs → 15 upper-triangular pairs → 8 chunks at 2 pairs each.
const N_PAIRS: usize = 15;
const CHUNK_PAIRS: usize = 2;
const N_CHUNKS: usize = 8;

fn tiny_cfg() -> PairwiseConfig {
    PairwiseConfig {
        seed: SEED,
        workers: 2,
        spar: SparGwConfig {
            sample_size: 48,
            outer_iters: 3,
            inner_iters: 6,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn tiny_dataset() -> GraphDataset {
    let mut ds = imdb_b(3);
    ds.graphs.truncate(6);
    ds
}

/// Fresh per-test scratch directory (removed up front so reruns of a
/// failed test never see stale state).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("spargw-ft-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir
}

fn grid_bits(g: &GramResult) -> Vec<u64> {
    g.distances.data().iter().map(|v| v.to_bits()).collect()
}

fn plain_gram() -> GramResult {
    PairwiseEngine::new(tiny_cfg(), EngineConfig::default())
        .gram(&tiny_dataset())
        .expect("baseline gram")
}

fn claim_run(
    dir: &Path,
    worker: &str,
    lease_ms: u64,
    sink: Option<PathBuf>,
) -> spargw::util::error::Result<GramResult> {
    let claim = ClaimConfig {
        dir: dir.to_path_buf(),
        worker: worker.to_string(),
        lease_ms,
        chunk_pairs: CHUNK_PAIRS,
    };
    let opts = EngineConfig { claim: Some(claim), sink, ..Default::default() };
    PairwiseEngine::new(tiny_cfg(), opts).gram(&tiny_dataset())
}

/// Plant a claim file as a crashed foreign worker would leave it: holder
/// metadata intact, heartbeat dead. With `lease_ms: 0` the lease is
/// expired on the first look, no sleeping required.
fn plant_dead_claim(dir: &Path, chunk: usize) {
    let claims = dir.join("claims");
    std::fs::create_dir_all(&claims).expect("claims dir");
    std::fs::write(
        claims.join(format!("chunk-{chunk}.claim")),
        "worker=ghost pid=999999999 beat=0\n",
    )
    .expect("plant claim");
}

fn sink_pair_count(path: &Path) -> usize {
    std::fs::read_to_string(path)
        .expect("read sink")
        .lines()
        .filter(|l| l.starts_with("pair "))
        .count()
}

#[test]
fn single_worker_claim_run_is_bit_identical_to_plain_gram() {
    let base = plain_gram();
    let dir = scratch("solo");
    let g = claim_run(&dir, "solo", 5000, None).expect("claim run");
    assert_eq!(grid_bits(&g), grid_bits(&base));
    assert_eq!(g.computed_pairs, N_PAIRS);
    assert_eq!(g.resumed_pairs, 0);
    assert_eq!(g.shards_run, N_CHUNKS);
    assert_eq!(g.shards_skipped, 0);
    let stats = g.claims.expect("claim-mode stats");
    assert_eq!(stats.claimed, N_CHUNKS as u64);
    assert_eq!(stats.reclaimed, 0);
    assert_eq!(stats.lease_expired, 0);
    // The counters surface through the metrics summary.
    assert!(
        g.metrics.summary().contains("claimed=8 "),
        "{}",
        g.metrics.summary()
    );
}

#[test]
fn failed_worker_hands_off_to_a_survivor_bit_for_bit() {
    let base = plain_gram();
    let dir = scratch("handoff");

    // Worker alpha's part publishes break permanently after the first
    // commit: it commits chunk 0, then errors out of chunk 1 once the
    // bounded retry is exhausted.
    let err = match fault::with_fault("part.publish:2+", || claim_run(&dir, "alpha", 5000, None))
    {
        Err(e) => e,
        Ok(_) => panic!("persistent publish failure must surface"),
    };
    let msg = format!("{err}");
    assert!(msg.contains("part.publish"), "{msg}");
    assert!(msg.contains("committing chunk 1"), "{msg}");
    assert!(msg.contains("attempts"), "{msg}");

    // Worker bravo picks up everything alpha did not finish and merges
    // alpha's committed chunk back in.
    let out = dir.join("merged.sink");
    let g = claim_run(&dir, "bravo", 5000, Some(out.clone())).expect("survivor run");
    assert_eq!(grid_bits(&g), grid_bits(&base));
    assert_eq!(g.resumed_pairs, CHUNK_PAIRS, "alpha committed exactly chunk 0");
    assert_eq!(g.computed_pairs, N_PAIRS - CHUNK_PAIRS);
    assert_eq!(g.shards_run, N_CHUNKS - 1);
    assert_eq!(g.shards_skipped, 1);
    assert_eq!(g.claims.expect("stats").claimed, (N_CHUNKS - 1) as u64);
    assert_eq!(sink_pair_count(&out), N_PAIRS);
}

#[test]
fn expired_lease_of_a_dead_worker_is_reclaimed() {
    let base = plain_gram();
    let dir = scratch("ghost");
    plant_dead_claim(&dir, 0);

    let g = claim_run(&dir, "survivor", 0, None).expect("survivor run");
    assert_eq!(grid_bits(&g), grid_bits(&base));
    assert_eq!(g.computed_pairs, N_PAIRS, "the ghost committed nothing");
    let stats = g.claims.expect("stats");
    assert!(stats.lease_expired >= 1, "{stats:?}");
    assert!(stats.reclaimed >= 1, "{stats:?}");
    assert_eq!(stats.claimed, N_CHUNKS as u64);
}

#[test]
fn transient_claim_faults_are_absorbed_and_results_stay_bit_identical() {
    let base = plain_gram();
    let points = [
        "claim.create",
        "claim.reclaim",
        "claim.release",
        "chunk.done",
        "part.write",
        "part.publish",
        "merge.write",
        "merge.publish",
    ];
    for point in points {
        for kind in ["io-error", "partial-write"] {
            let spec = format!("{point}:1:{kind}");
            let dir = scratch(&format!("mx-{}-{kind}", point.replace('.', "-")));
            // An expired foreign claim on chunk 0 routes the run through
            // the reclaim path, so `claim.reclaim` is actually hit.
            plant_dead_claim(&dir, 0);
            let out = dir.join("merged.sink");

            // One transient blip on any protocol point is absorbed by
            // the bounded retry (release failures are tolerated
            // outright), so the injected run itself must succeed.
            let g = fault::with_fault(&spec, || claim_run(&dir, "victim", 0, Some(out.clone())))
                .unwrap_or_else(|e| panic!("{spec}: injected run failed: {e}"));
            assert_eq!(grid_bits(&g), grid_bits(&base), "{spec}");
            if point != "claim.release" {
                assert!(
                    g.claims.expect("stats").retried >= 1,
                    "{spec}: the absorbed blip must be counted"
                );
            }
            assert_eq!(sink_pair_count(&out), N_PAIRS, "{spec}");

            // A later worker over the finished dir recomputes nothing
            // and republishes the identical merged sink.
            let r = claim_run(&dir, "recovery", 5000, Some(out.clone()))
                .unwrap_or_else(|e| panic!("{spec}: recovery failed: {e}"));
            assert_eq!(grid_bits(&r), grid_bits(&base), "{spec}");
            assert_eq!(r.computed_pairs, 0, "{spec}");
            assert_eq!(r.resumed_pairs, N_PAIRS, "{spec}");
            assert_eq!(sink_pair_count(&out), N_PAIRS, "{spec}");
        }
    }
}

#[test]
fn sink_path_faults_leave_a_resumable_checkpoint() {
    let base = plain_gram();
    let shard_run = |sink: &Path, resume: bool| {
        let opts = EngineConfig {
            sink: Some(sink.to_path_buf()),
            resume,
            ..Default::default()
        };
        PairwiseEngine::new(tiny_cfg(), opts).gram(&tiny_dataset())
    };
    for point in ["sink.base", "sink.append", "lock.acquire"] {
        for kind in ["io-error", "partial-write"] {
            let spec = format!("{point}:1:{kind}");
            let dir = scratch(&format!("sink-{}-{kind}", point.replace('.', "-")));
            let sink = dir.join("gram.sink");

            // Sink writes are deliberately not retried (an in-place
            // append retried after a partial write would duplicate
            // half-written lines), so the fault surfaces as an error …
            let err = match fault::with_fault(&spec, || shard_run(&sink, false)) {
                Err(e) => e,
                Ok(_) => panic!("{spec}: sink-path faults are never retried"),
            };
            let msg = format!("{err}");
            assert!(msg.contains("injected fault"), "{spec}: {msg}");

            // … and recovery is resume-time healing: whatever prefix
            // survived, a resume run trusts only done-marked shards,
            // recomputes the rest, and lands on the baseline bits.
            let g = shard_run(&sink, sink.exists())
                .unwrap_or_else(|e| panic!("{spec}: recovery failed: {e}"));
            assert_eq!(g.resumed_pairs, 0, "{spec}: a torn sink must not be trusted");
            assert_eq!(g.computed_pairs, N_PAIRS, "{spec}");
            assert_eq!(grid_bits(&g), grid_bits(&base), "{spec}");
            let text = std::fs::read_to_string(&sink).expect("healed sink");
            assert_eq!(
                text.lines().filter(|l| l.starts_with("pair ")).count(),
                N_PAIRS,
                "{spec}"
            );
            assert!(text.contains("\ndone 0\n"), "{spec}");
        }
    }
}

/// The kill -9 shape, end to end through the CLI binary: a worker
/// process is aborted mid-commit by an injected `abort` fault, then an
/// in-process survivor reclaims its expired lease, finishes the matrix
/// and merges — bit-identical to a clean baseline.
#[test]
fn aborted_worker_process_is_recovered_by_a_survivor() {
    // The in-process config mirrors the child's CLI flags exactly —
    // same config fingerprint, or the claim dir would refuse the merge.
    let mut solver_opts = BTreeMap::new();
    solver_opts.insert("s".to_string(), "64".to_string());
    solver_opts.insert("outer".to_string(), "3".to_string());
    solver_opts.insert("inner".to_string(), "8".to_string());
    let cfg = PairwiseConfig {
        solver: "spar_gw".to_string(),
        solver_opts,
        workers: 2,
        seed: SEED,
        ..Default::default()
    };
    let ds = graphsets::by_name("synthetic:6", SEED).expect("dataset");
    let base = PairwiseEngine::new(cfg.clone(), EngineConfig::default())
        .gram(&ds)
        .expect("baseline gram");

    let dir = scratch("abort");
    // The child commits chunk 0 (done-marker hit 1), then aborts on the
    // second commit's done-marker write — after publishing its part but
    // before the marker lands, with its chunk-1 claim still held.
    let output = std::process::Command::new(env!("CARGO_BIN_EXE_spargw"))
        .args([
            "pairwise",
            "--dataset",
            "synthetic:6",
            "--solver",
            "spar_gw",
            "--solver-opt",
            "s=64",
            "--solver-opt",
            "outer=3",
            "--solver-opt",
            "inner=8",
            "--workers",
            "2",
            "--seed",
            "17",
            "--claim-dir",
            dir.to_str().expect("utf-8 dir"),
            "--worker-id",
            "doomed",
            "--claim-chunk",
            "2",
        ])
        .env("SPARGW_FAULT", "chunk.done:2:abort")
        .output()
        .expect("spawn doomed worker");
    assert!(!output.status.success(), "the doomed worker must die");
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        stderr.contains("injected fault `chunk.done` (abort, hit 2)"),
        "child died for the wrong reason:\n{stderr}"
    );

    // Survivor: lease 0 forces the dead worker's chunk-1 claim to read
    // as expired immediately (deterministic — no waiting on mtimes).
    let claim = ClaimConfig {
        dir: dir.clone(),
        worker: "survivor".to_string(),
        lease_ms: 0,
        chunk_pairs: 2,
    };
    let out = dir.join("merged.sink");
    let opts = EngineConfig { claim: Some(claim), sink: Some(out.clone()), ..Default::default() };
    let g = PairwiseEngine::new(cfg, opts).gram(&ds).expect("survivor run");

    assert_eq!(grid_bits(&g), grid_bits(&base), "merged result diverged from baseline");
    assert_eq!(g.resumed_pairs, 2, "chunk 0 came back from the dead worker's part");
    assert_eq!(g.computed_pairs, 13);
    let stats = g.claims.expect("stats");
    assert!(stats.lease_expired >= 1, "{stats:?}");
    assert!(stats.reclaimed >= 1, "{stats:?}");
    assert_eq!(sink_pair_count(&out), 15);
}
