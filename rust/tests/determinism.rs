//! Determinism/conformance harness for the sharded pairwise Gram engine.
//!
//! The engine's contract is that every execution knob is a pure
//! throughput/operability choice: the Gram matrix must be **bit-identical**
//! across
//!   * worker-pool widths (the crate-wide pool of `runtime::pool`, swept
//!     via `spargw::testutil::pool_thread_levels` +
//!     `pool::with_thread_limit` — CI additionally pins the pool itself
//!     per matrix job through `SPARGW_THREADS`),
//!   * shard counts (1 vs 3) and single-shard multi-process partitioning,
//!   * the cached path (per-structure preprocessing shared across pairs)
//!     vs the uncached per-pair re-derivation,
//!   * fresh runs vs sink-resumed runs,
//!   * SIMD kernel backends (`kernel::simd`: the portable scalar
//!     schedule vs the detected vector backend, crossed with pool widths
//!     — for all registry solvers and the prepared pairwise path; CI
//!     additionally pins the process-wide backend per matrix job through
//!     `SPARGW_SIMD`),
//! for spar_gw, spar_fgw and spar_ugw on seeded toy datasets — plus a
//! single-solve pool-width matrix over **every registry solver** (the
//! hierarchical qgw tier and the factored low-rank plan included, and
//! qgw additionally through its O(n)-memory point-cloud entry) and a
//! pool-reuse check (the worker count stays constant across repeated
//! solves; parallel regions never re-spawn threads). The
//! reference each variant is compared against is the *direct* pre-engine
//! path: a plain loop over pairs calling `GwSolver::solve`/`solve_fused`
//! with the historical RNG derivation — exactly what the coordinator did
//! before the engine existed.

use spargw::coordinator::engine::{EngineConfig, PairwiseEngine};
use spargw::coordinator::service::PairwiseConfig;
use spargw::datasets::graphsets::{attribute_distance, bzr, imdb_b, GraphDataset};
use spargw::gw::core::Workspace;
use spargw::gw::fgw::FgwProblem;
use spargw::gw::solver::{Plan, SolverRegistry};
use spargw::gw::GwProblem;
use spargw::kernel::simd::{self, Backend};
use spargw::linalg::Mat;
use spargw::rng::{derive_seed, Rng};
use spargw::runtime::pool::{pool, with_thread_limit};
use spargw::testutil::pool_thread_levels;

const SEED: u64 = 17;

/// Small structure-only dataset (8 IMDB-like graphs).
fn plain_dataset() -> GraphDataset {
    let mut ds = imdb_b(3);
    ds.graphs.truncate(8);
    ds
}

/// Small attributed dataset (8 BZR-like graphs) — exercises the fused
/// objective for solvers that support it.
fn attributed_dataset() -> GraphDataset {
    let mut ds = bzr(4);
    ds.graphs.truncate(8);
    ds
}

fn config(solver: &str) -> PairwiseConfig {
    let mut cfg = PairwiseConfig {
        solver: solver.to_string(),
        workers: 2,
        seed: SEED,
        ..Default::default()
    };
    // Keep the toy runs fast but non-trivial; 384 draws give the chunked
    // cost kernel enough rows to engage on the larger pairs.
    cfg.spar.sample_size = 384;
    cfg.spar.outer_iters = 4;
    cfg.spar.inner_iters = 8;
    cfg
}

/// The pre-engine direct path: per-pair solve through the registry
/// solver, historical RNG streams, no cache, no shards.
fn direct_reference(ds: &GraphDataset, cfg: &PairwiseConfig) -> Mat {
    let solver = cfg.build_solver().expect("reference solver");
    let n = ds.len();
    let marginals: Vec<Vec<f64>> = ds.graphs.iter().map(|g| g.marginal()).collect();
    let mut out = Mat::zeros(n, n);
    let mut ws = Workspace::new();
    for i in 0..n {
        for j in (i + 1)..n {
            let gi = &ds.graphs[i];
            let gj = &ds.graphs[j];
            let p = GwProblem::new(&gi.adj, &gj.adj, &marginals[i], &marginals[j]);
            let mut rng = Rng::new(derive_seed(cfg.seed, (i * n + j) as u64));
            let report = match attribute_distance(gi, gj) {
                Some(feat) if solver.supports_fused() => {
                    let fp = FgwProblem::new(p, &feat, cfg.alpha);
                    solver.solve_fused(&fp, &mut rng, &mut ws).expect("fused solve")
                }
                _ => solver.solve(&p, &mut rng, &mut ws).expect("solve"),
            };
            out[(i, j)] = report.value;
            out[(j, i)] = report.value;
        }
    }
    out
}

fn engine_gram(ds: &GraphDataset, cfg: &PairwiseConfig, opts: EngineConfig) -> Mat {
    PairwiseEngine::new(cfg.clone(), opts).gram(ds).expect("engine gram").distances
}

fn assert_bits_equal(a: &Mat, b: &Mat, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape");
    for (k, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: entry {k} differs ({x} vs {y})"
        );
    }
}

fn dataset_for(solver: &str) -> GraphDataset {
    // spar_fgw exercises its fused objective on attributed graphs; the
    // others run structure-only.
    if solver == "spar_fgw" {
        attributed_dataset()
    } else {
        plain_dataset()
    }
}

#[test]
fn gram_bit_identical_across_pool_widths_shards_and_cache() {
    for solver in ["spar_gw", "spar_fgw", "spar_ugw"] {
        let ds = dataset_for(solver);
        // Reference: serial kernels, direct pre-engine path.
        let reference =
            with_thread_limit(1, || direct_reference(&ds, &config(solver)));
        for width in pool_thread_levels() {
            let cfg = config(solver);
            for shards in [1usize, 3] {
                for use_cache in [true, false] {
                    let opts = EngineConfig { shards, use_cache, ..Default::default() };
                    let got =
                        with_thread_limit(width, || engine_gram(&ds, &cfg, opts));
                    assert_bits_equal(
                        &reference,
                        &got,
                        &format!(
                            "{solver}: pool_width={width} \
                             shards={shards} cache={use_cache}"
                        ),
                    );
                }
            }
        }
    }
}

/// The plan's stored values (dense data, sparse entry values, or the
/// concatenated low-rank factors), for bitwise comparison.
fn plan_vals(plan: &Plan) -> Vec<f64> {
    match plan {
        Plan::Dense(t) => t.data().to_vec(),
        Plan::Sparse(t) => t.vals().to_vec(),
        Plan::Factored(t) => {
            let mut v = t.q.data().to_vec();
            v.extend_from_slice(t.r.data());
            v.extend_from_slice(&t.g);
            v
        }
    }
}

#[test]
fn all_registry_solvers_bit_identical_across_pool_widths() {
    // Every parallelized path — dense matmul/matvec (Alg.1 family,
    // LR-GW, S-GWL, SaGroW, anchor), CSR spmv/gathered transposes,
    // Sinkhorn updates, the Eq. (5) factor build and the O(s²) cost
    // kernels (Spar-*) — must produce bit-identical plans and costs at
    // every pool width. n = 96 puts the blocked matmul past its
    // rows-per-chunk gate (⌈2^15/96²⌉ = 4 rows) and the default
    // s = 16n = 1536 puts the gathered cost kernel past its
    // entries-per-chunk gate, so the pooled paths genuinely execute at
    // widths > 1 rather than falling back to the inline branch.
    let n = 96;
    let mut grng = spargw::rng::Xoshiro256::new(0xD157);
    let cx = spargw::testutil::random_relation(&mut grng, n);
    let cy = spargw::testutil::random_relation(&mut grng, n);
    let a = spargw::util::uniform(n);
    let b = spargw::util::uniform(n);
    let p = GwProblem::new(&cx, &cy, &a, &b);
    // Short schedules keep the registry-wide × three-width sweep fast; the
    // bit-identity property is schedule-independent.
    let base = spargw::gw::solver::SolverBase {
        outer_iters: 3,
        inner_iters: 10,
        ..Default::default()
    };
    for &name in SolverRegistry::names() {
        let solver =
            SolverRegistry::build_with_base(name, &Default::default(), &base).expect(name);
        let solve_at = |width: usize| {
            with_thread_limit(width, || {
                let mut rng = Rng::new(derive_seed(SEED, 77));
                let mut ws = Workspace::new();
                solver.solve(&p, &mut rng, &mut ws).expect(name)
            })
        };
        let reference = solve_at(1);
        let ref_vals = plan_vals(&reference.plan);
        for width in [2usize, 8] {
            let got = solve_at(width);
            assert_eq!(
                reference.value.to_bits(),
                got.value.to_bits(),
                "{name}: value differs at pool width {width} \
                 ({} vs {})",
                reference.value,
                got.value
            );
            assert_eq!(
                reference.outer_iters, got.outer_iters,
                "{name}: iteration schedule differs at width {width}"
            );
            let got_vals = plan_vals(&got.plan);
            assert_eq!(ref_vals.len(), got_vals.len(), "{name}: plan size");
            for (l, (x, y)) in ref_vals.iter().zip(&got_vals).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{name}: plan entry {l} differs at width {width} ({x} vs {y})"
                );
            }
        }
    }
}

#[test]
fn all_registry_solvers_bit_identical_across_simd_backends() {
    // The SIMD kernel backend is a throughput knob exactly like the pool
    // width: every registry solver must produce a bit-identical value,
    // iteration schedule and plan under the portable scalar schedule and
    // under the detected vector backend (AVX2/NEON where available — on
    // machines without one, detect() is Scalar and this degenerates to a
    // self-comparison, which CI's x86_64 runner rules out for AVX2). The
    // backend override is resolved at submit time and captured into pool
    // chunks, so the matrix crosses it with pool widths 1 and 8.
    let n = 96;
    let mut grng = spargw::rng::Xoshiro256::new(0xD157);
    let cx = spargw::testutil::random_relation(&mut grng, n);
    let cy = spargw::testutil::random_relation(&mut grng, n);
    let a = spargw::util::uniform(n);
    let b = spargw::util::uniform(n);
    let p = GwProblem::new(&cx, &cy, &a, &b);
    let base = spargw::gw::solver::SolverBase {
        outer_iters: 3,
        inner_iters: 10,
        ..Default::default()
    };
    let best = simd::detect();
    for &name in SolverRegistry::names() {
        let solver =
            SolverRegistry::build_with_base(name, &Default::default(), &base).expect(name);
        let solve_at = |backend: Backend, width: usize| {
            simd::with_backend_override(backend, || {
                with_thread_limit(width, || {
                    let mut rng = Rng::new(derive_seed(SEED, 91));
                    let mut ws = Workspace::new();
                    solver.solve(&p, &mut rng, &mut ws).expect(name)
                })
            })
        };
        let reference = solve_at(Backend::Scalar, 1);
        let ref_vals = plan_vals(&reference.plan);
        for backend in [Backend::Scalar, best] {
            for width in [1usize, 8] {
                if backend == Backend::Scalar && width == 1 {
                    continue; // the reference itself
                }
                let got = solve_at(backend, width);
                assert_eq!(
                    reference.value.to_bits(),
                    got.value.to_bits(),
                    "{name}: value differs at simd={} width={width} ({} vs {})",
                    backend.name(),
                    reference.value,
                    got.value
                );
                assert_eq!(
                    reference.outer_iters,
                    got.outer_iters,
                    "{name}: iteration schedule differs at simd={} width={width}",
                    backend.name()
                );
                let got_vals = plan_vals(&got.plan);
                assert_eq!(ref_vals.len(), got_vals.len(), "{name}: plan size");
                for (l, (x, y)) in ref_vals.iter().zip(&got_vals).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{name}: plan entry {l} differs at simd={} width={width} ({x} vs {y})",
                        backend.name()
                    );
                }
            }
        }
    }
}

#[test]
fn qgw_point_path_bit_identical_across_pool_widths_and_simd() {
    // The million-point entry (implicit Euclidean relations over point
    // clouds — no n×n matrix anywhere) under the same knob matrix as the
    // registry solvers: pool width and SIMD backend must leave the value,
    // iteration schedule and the extended sparse plan bit-identical.
    let n = 80;
    let mut grng = Rng::new(0xBEE5);
    let xs: Vec<Vec<f64>> =
        (0..n).map(|_| (0..3).map(|_| grng.f64()).collect()).collect();
    let ys: Vec<Vec<f64>> =
        (0..n).map(|_| (0..3).map(|_| grng.f64()).collect()).collect();
    let px = spargw::gw::PointCloud::from_points(&xs);
    let py = spargw::gw::PointCloud::from_points(&ys);
    let a = spargw::util::uniform(n);
    let b = spargw::util::uniform(n);
    let solver = spargw::gw::qgw::build(
        &Default::default(),
        &spargw::gw::SolverBase::default(),
    )
    .expect("qgw build");
    let solve_at = |backend: Backend, width: usize| {
        simd::with_backend_override(backend, || {
            with_thread_limit(width, || {
                let mut rng = Rng::new(derive_seed(SEED, 123));
                let mut ws = Workspace::new();
                solver.solve_points(&px, &py, &a, &b, &mut rng, &mut ws).expect("qgw points")
            })
        })
    };
    let reference = solve_at(Backend::Scalar, 1);
    let ref_vals = plan_vals(&reference.plan);
    let best = simd::detect();
    for backend in [Backend::Scalar, best] {
        for width in [1usize, 8] {
            if backend == Backend::Scalar && width == 1 {
                continue; // the reference itself
            }
            let got = solve_at(backend, width);
            assert_eq!(
                reference.value.to_bits(),
                got.value.to_bits(),
                "qgw points: value differs at simd={} width={width}",
                backend.name()
            );
            assert_eq!(reference.outer_iters, got.outer_iters, "qgw points: schedule");
            let got_vals = plan_vals(&got.plan);
            assert_eq!(ref_vals.len(), got_vals.len(), "qgw points: plan size");
            for (l, (x, y)) in ref_vals.iter().zip(&got_vals).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "qgw points: plan entry {l} differs at simd={} width={width}",
                    backend.name()
                );
            }
        }
    }
}

#[test]
fn pairwise_gram_bit_identical_across_simd_backends() {
    // The prepared pairwise path (engine + structure cache + scheduler
    // workers) under the backend matrix: the scheduler re-applies the
    // submit-time backend on every worker thread, so pinning a backend
    // around a whole Gram run governs all of its kernels. Each variant
    // must reproduce the serial scalar reference bit-for-bit.
    let ds = plain_dataset();
    let cfg = config("spar_gw");
    let reference = simd::with_backend_override(Backend::Scalar, || {
        with_thread_limit(1, || engine_gram(&ds, &cfg, EngineConfig::default()))
    });
    let best = simd::detect();
    for backend in [Backend::Scalar, best] {
        for width in [1usize, 8] {
            let got = simd::with_backend_override(backend, || {
                with_thread_limit(width, || engine_gram(&ds, &cfg, EngineConfig::default()))
            });
            assert_bits_equal(
                &reference,
                &got,
                &format!("prepared pairwise: simd={} width={width}", backend.name()),
            );
        }
    }
}

#[test]
fn pool_workers_constant_across_repeated_solves() {
    // The pool spawns its workers at most once (lazily); repeated solves
    // must reuse them — the spawn-per-invocation cost the pool replaces
    // must not creep back in.
    let mut ds = imdb_b(10);
    ds.graphs.truncate(2);
    let (a, b) = (ds.graphs[0].marginal(), ds.graphs[1].marginal());
    let p = GwProblem::new(&ds.graphs[0].adj, &ds.graphs[1].adj, &a, &b);
    let solver = SolverRegistry::build("spar_gw", &Default::default()).unwrap();
    let mut ws = Workspace::new();
    let mut rng = Rng::new(1);
    // Pin the lazy spawn deterministically (warm_up is idempotent and
    // independent of concurrent tests' reservations), so the worker
    // count is final for the process before the first observation.
    pool().warm_up();
    let expected = pool().threads().saturating_sub(1);
    assert_eq!(pool().workers_spawned(), expected, "warm_up spawn count");
    for _ in 0..6 {
        let _ = solver.solve(&p, &mut rng, &mut ws).unwrap();
        assert_eq!(
            pool().workers_spawned(),
            expected,
            "repeated solves changed the pool's worker count"
        );
    }
}

#[test]
fn sharded_processes_cover_the_reference_exactly() {
    // Simulate multi-process partitioning: three engines each running one
    // shard; their merged (summed) outputs must reproduce the reference
    // bit-for-bit with no overlap.
    for solver in ["spar_gw", "spar_ugw"] {
        let ds = plain_dataset();
        let cfg = config(solver);
        let reference = direct_reference(&ds, &cfg);
        let n = ds.len();
        let mut merged = Mat::zeros(n, n);
        for shard in 0..3 {
            let opts = EngineConfig {
                shards: 3,
                only_shard: Some(shard),
                ..Default::default()
            };
            let part = engine_gram(&ds, &cfg, opts);
            for (m, p) in merged.data_mut().iter_mut().zip(part.data()) {
                if *p != 0.0 {
                    assert_eq!(*m, 0.0, "{solver}: shards overlap");
                    *m = *p;
                }
            }
        }
        assert_bits_equal(&reference, &merged, &format!("{solver}: 3-way shard merge"));
    }
}

#[test]
fn preprocessing_runs_exactly_once_per_structure_k40() {
    // The acceptance criterion: a K=40 toy pairwise run performs each
    // structure's preprocessing exactly once, while serving two cached
    // look-ups per pair.
    let mut ds = imdb_b(6);
    ds.graphs.truncate(40);
    let k = ds.len();
    assert_eq!(k, 40);
    let mut cfg = config("spar_gw");
    cfg.workers = 4;
    cfg.spar.sample_size = 48;
    cfg.spar.outer_iters = 2;
    cfg.spar.inner_iters = 4;
    let g = PairwiseEngine::new(cfg, EngineConfig::default())
        .gram(&ds)
        .expect("K=40 gram");
    let pairs = k * (k - 1) / 2;
    assert_eq!(g.computed_pairs, pairs);
    assert_eq!(g.cache.built, k, "preprocessing must run once per structure");
    assert_eq!(g.cache.hits, 2 * pairs, "two cached look-ups per pair");
}

// ---------------------------------------------------------------------
// Sink checkpoint/resume correctness.
// ---------------------------------------------------------------------

fn temp_sink(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("spargw_determinism_tests");
    std::fs::create_dir_all(&dir).expect("temp dir");
    dir.join(name)
}

#[test]
fn resume_after_partial_run_matches_uninterrupted_run() {
    let ds = plain_dataset();
    let cfg = config("spar_gw");
    let reference = direct_reference(&ds, &cfg);

    // "Kill after k shards": run only shards 0 and 1 of 3, checkpointing
    // to the sink, then resume the full job.
    let sink = temp_sink("resume_partial.sink");
    std::fs::remove_file(&sink).ok();
    for shard in 0..2 {
        let opts = EngineConfig {
            shards: 3,
            only_shard: Some(shard),
            sink: Some(sink.clone()),
            resume: shard > 0, // first run creates the sink, second appends
            ..Default::default()
        };
        let g = PairwiseEngine::new(cfg.clone(), opts).gram(&ds).expect("partial run");
        assert_eq!(g.shards_run, 1);
    }

    let opts = EngineConfig {
        shards: 3,
        sink: Some(sink.clone()),
        resume: true,
        ..Default::default()
    };
    let g = PairwiseEngine::new(cfg.clone(), opts).gram(&ds).expect("resumed run");
    // Two shards restored from the sink, one computed.
    assert_eq!(g.shards_skipped, 2);
    assert_eq!(g.shards_run, 1);
    assert!(g.resumed_pairs > 0);
    let n = ds.len();
    assert_eq!(g.resumed_pairs + g.computed_pairs, n * (n - 1) / 2);
    assert_bits_equal(&reference, &g.distances, "resume merge");
    std::fs::remove_file(&sink).ok();
}

#[test]
fn truncated_sink_tail_recomputes_the_partial_shard() {
    // Simulate a run killed mid-write: take a complete 3-shard sink,
    // chop it inside the last shard's block (no `done` marker, possibly a
    // half-written line), and resume. The damaged shard must be
    // recomputed and the final matrix still match the reference.
    let ds = plain_dataset();
    let cfg = config("spar_gw");
    let reference = direct_reference(&ds, &cfg);

    let sink = temp_sink("resume_truncated.sink");
    std::fs::remove_file(&sink).ok();
    let opts = EngineConfig {
        shards: 3,
        sink: Some(sink.clone()),
        ..Default::default()
    };
    let g = PairwiseEngine::new(cfg.clone(), opts).gram(&ds).expect("full run");
    assert_eq!(g.shards_run, 3);
    assert_bits_equal(&reference, &g.distances, "full sink run");

    // Chop the file mid-way through the final shard's block, leaving a
    // dangling half line.
    let text = std::fs::read_to_string(&sink).expect("read sink");
    let last_done = text.rfind("\ndone ").expect("final done marker");
    let truncated = &text[..last_done - 20];
    std::fs::write(&sink, truncated).expect("truncate sink");

    let opts = EngineConfig {
        shards: 3,
        sink: Some(sink.clone()),
        resume: true,
        ..Default::default()
    };
    let g = PairwiseEngine::new(cfg.clone(), opts).gram(&ds).expect("resume truncated");
    assert_eq!(g.shards_skipped, 2, "intact shards are skipped");
    assert_eq!(g.shards_run, 1, "damaged shard is recomputed");
    assert_bits_equal(&reference, &g.distances, "truncated-tail resume");
    std::fs::remove_file(&sink).ok();
}

#[test]
fn resumed_sink_is_replay_complete() {
    // After a fully resumed run the sink contains every shard's `done`
    // marker, so a further resume computes nothing at all.
    let ds = plain_dataset();
    let cfg = config("spar_gw");
    let sink = temp_sink("resume_complete.sink");
    std::fs::remove_file(&sink).ok();
    let mk = |resume: bool| EngineConfig {
        shards: 2,
        sink: Some(sink.clone()),
        resume,
        ..Default::default()
    };
    let first = PairwiseEngine::new(cfg.clone(), mk(false)).gram(&ds).expect("first");
    let replay = PairwiseEngine::new(cfg.clone(), mk(true)).gram(&ds).expect("replay");
    assert_eq!(replay.computed_pairs, 0);
    assert_eq!(replay.shards_skipped, 2);
    let n = ds.len();
    assert_eq!(replay.resumed_pairs, n * (n - 1) / 2);
    assert_bits_equal(&first.distances, &replay.distances, "replayed sink");
    std::fs::remove_file(&sink).ok();
}
