//! Integration tests for the L3 coordinator: the pairwise service end to
//! end (native path), bucketing/padding correctness, scheduler
//! determinism, and the §6.2 pipeline through clustering.

use spargw::bench::{pairwise_distances, Method, RunSettings};
use spargw::coordinator::bucket::{choose_bucket, pad_marginal, pad_relation};
use spargw::coordinator::service::{similarity_from_distances, PairwiseConfig, PairwiseGw};
use spargw::datasets::graphsets::{imdb_b, synthetic_ds};
use spargw::gw::spar_gw::{spar_gw_with_set, SparGwConfig};
use spargw::gw::sampling::GwSampler;
use spargw::gw::{GroundCost, GwProblem};
use spargw::ml::{rand_index, spectral_clustering};
use spargw::rng::Xoshiro256;

fn small_ds(n_keep: usize, seed: u64) -> spargw::datasets::graphsets::GraphDataset {
    let mut ds = imdb_b(seed);
    ds.graphs.truncate(n_keep);
    ds
}

#[test]
fn pairwise_service_native_path_end_to_end() {
    let ds = small_ds(10, 1);
    let cfg = PairwiseConfig { workers: 3, seed: 5, ..Default::default() };
    let mut svc = PairwiseGw::new(cfg);
    let res = svc.pairwise(&ds).unwrap();
    assert_eq!(res.native_pairs, 45);
    assert_eq!(res.pjrt_pairs, 0);
    assert_eq!(res.metrics.count(), 45);
    for i in 0..10 {
        assert_eq!(res.distances[(i, i)], 0.0);
        for j in 0..10 {
            assert_eq!(res.distances[(i, j)], res.distances[(j, i)]);
            assert!(res.distances[(i, j)] >= 0.0);
        }
    }
    assert!(res.metrics.throughput() > 0.0);
    assert!(res.metrics.percentile(0.99) >= res.metrics.percentile(0.50));
}

#[test]
fn pairwise_service_deterministic_across_worker_counts() {
    let ds = small_ds(8, 2);
    let mk = |workers| {
        let cfg = PairwiseConfig { workers, seed: 9, ..Default::default() };
        PairwiseGw::new(cfg).pairwise(&ds).unwrap().distances
    };
    let d1 = mk(1);
    let d4 = mk(4);
    for (x, y) in d1.data().iter().zip(d4.data()) {
        assert_eq!(x, y);
    }
}

#[test]
fn attributed_dataset_routes_through_fgw() {
    // SYNTHETIC carries vector attributes: distances must differ from the
    // structure-only run because the fused term contributes.
    let mut ds = synthetic_ds(3);
    ds.graphs.truncate(6);
    let cfg = PairwiseConfig { workers: 2, seed: 4, ..Default::default() };
    let fused = PairwiseGw::new(cfg.clone()).pairwise(&ds).unwrap().distances;
    // Strip attributes -> plain Spar-GW.
    for g in &mut ds.graphs {
        g.attrs.clear();
    }
    let plain = PairwiseGw::new(cfg).pairwise(&ds).unwrap().distances;
    let diff: f64 = fused.data().iter().zip(plain.data()).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 1e-6, "fused and plain distances identical (diff {diff})");
}

#[test]
fn bucket_padding_preserves_spar_gw_result() {
    // Padding (C, a) to a larger bucket with zero mass must not change
    // the solution: padded rows carry no probability.
    let n = 20;
    let pad_n = 32;
    let mut rng = Xoshiro256::new(11);
    let inst = spargw::bench::Workload::Moon.make(n, &mut rng);
    let p = inst.problem();
    let sampler = GwSampler::new(p.a, p.b, 0.0);
    let set = sampler.sample_iid(&mut rng, 16 * n);

    let cfg = SparGwConfig { sample_size: 16 * n, ..Default::default() };
    let base = spar_gw_with_set(&p, GroundCost::L2, &cfg, &set);

    let cx_pad = pad_relation(&inst.cx, pad_n);
    let cy_pad = pad_relation(&inst.cy, pad_n);
    let a_pad = pad_marginal(&inst.a, pad_n);
    let b_pad = pad_marginal(&inst.b, pad_n);
    let p_pad = GwProblem::new(&cx_pad, &cy_pad, &a_pad, &b_pad);
    let padded = spar_gw_with_set(&p_pad, GroundCost::L2, &cfg, &set);

    assert!(
        (base.value - padded.value).abs() < 1e-9,
        "padding changed the value: {} vs {}",
        base.value,
        padded.value
    );
}

#[test]
fn choose_bucket_picks_smallest_fit() {
    let buckets = [32, 64, 128];
    assert_eq!(choose_bucket(20, &buckets), Some(32));
    assert_eq!(choose_bucket(32, &buckets), Some(32));
    assert_eq!(choose_bucket(33, &buckets), Some(64));
    assert_eq!(choose_bucket(128, &buckets), Some(128));
    assert_eq!(choose_bucket(129, &buckets), None);
}

#[test]
fn full_clustering_pipeline_recovers_classes() {
    // SYNTHETIC's two motif classes are easy: the full pipeline should
    // reach a high Rand index.
    let mut ds = synthetic_ds(7);
    ds.graphs.truncate(20);
    let cfg = PairwiseConfig { workers: 4, seed: 7, ..Default::default() };
    let res = PairwiseGw::new(cfg).pairwise(&ds).unwrap();
    let sim = similarity_from_distances(&res.distances, 0.1);
    let mut best = 0.0f64;
    for rep in 0..5u64 {
        let mut rng = Xoshiro256::new(rep);
        let ri = rand_index(&spectral_clustering(&sim, ds.n_classes, &mut rng), &ds.labels());
        best = best.max(ri);
    }
    assert!(best > 0.8, "pipeline RI {best}");
}

#[test]
fn bench_pairwise_matches_coordinator_for_spar_gw() {
    // The harness helper and the production service agree in
    // distribution: both produce finite symmetric matrices on the same
    // dataset (values differ by RNG stream conventions).
    let ds = small_ds(6, 13);
    let st = RunSettings::default();
    let d = pairwise_distances(&ds, Method::SparGw, GroundCost::L2, &st, 2, 13);
    let cfg = PairwiseConfig { workers: 2, seed: 13, ..Default::default() };
    let res = PairwiseGw::new(cfg).pairwise(&ds).unwrap();
    for i in 0..6 {
        for j in 0..6 {
            assert!(d[(i, j)].is_finite());
            assert!(res.distances[(i, j)].is_finite());
        }
    }
}
