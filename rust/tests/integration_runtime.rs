//! Integration tests for the PJRT runtime: loading the AOT artifacts
//! (JAX + Pallas lowered to HLO text by `make artifacts`), executing
//! them from Rust, and cross-checking against the native solver.
//!
//! These tests are skipped (with a notice) when `artifacts/` has not been
//! built — `make artifacts` first.

use spargw::bench::Workload;
use spargw::gw::sampling::GwSampler;
use spargw::gw::spar_gw::{spar_gw_with_set, SparGwConfig};
use spargw::gw::GroundCost;
use spargw::rng::Xoshiro256;
use spargw::runtime::artifacts::Manifest;
use spargw::runtime::Runtime;

fn artifact_dir() -> Option<String> {
    let dir = std::env::var("SPARGW_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    });
    if Manifest::load(&dir).is_ok() {
        Some(dir)
    } else {
        eprintln!("skipping runtime test: no artifacts in {dir} (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_loads_and_describes_buckets() {
    let Some(dir) = artifact_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert!(!m.specs.is_empty());
    // Every artifact file referenced by the manifest exists.
    for spec in &m.specs {
        let path = m.path_of(spec);
        assert!(path.exists(), "{path:?} missing");
    }
    // Spar-GW buckets exist for both costs.
    for cost in [GroundCost::L1, GroundCost::L2] {
        let buckets = m.spar_buckets(cost);
        assert!(!buckets.is_empty(), "no {cost:?} buckets");
    }
}

#[test]
fn pjrt_spar_gw_matches_native_solver() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();

    let n = 30;
    let mut rng = Xoshiro256::new(21);
    let inst = Workload::Moon.make(n, &mut rng);
    let p = inst.problem();
    let (_bucket_n, bucket_s) = rt.spar_gw_bucket(GroundCost::L2, n).expect("bucket");

    // Sample with the bucket's budget so native and PJRT share the set.
    let sampler = GwSampler::new(p.a, p.b, 0.0);
    let set = sampler.sample_iid(&mut rng, bucket_s);

    let out = rt.run_spar_gw(GroundCost::L2, &inst.cx, &inst.cy, &inst.a, &inst.b, &set).unwrap();

    let cfg = SparGwConfig { sample_size: bucket_s, ..Default::default() };
    let native = spar_gw_with_set(&p, GroundCost::L2, &cfg, &set);

    // f32 artifact vs f64 native: agreement to a few decimal places.
    let rel = (out.gw - native.value).abs() / native.value.abs().max(1e-6);
    assert!(
        rel < 0.15,
        "pjrt {} vs native {} (rel {rel})",
        out.gw,
        native.value
    );
    assert_eq!(out.t_vals.len(), set.len());
    let mass: f64 = out.t_vals.iter().map(|&v| v as f64).sum();
    assert!((mass - 1.0).abs() < 0.05, "pjrt plan mass {mass}");
}

#[test]
fn pjrt_executable_cache_reuses_compilations() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let n = 24;
    let mut rng = Xoshiro256::new(22);
    for _ in 0..3 {
        let inst = Workload::Graph.make(n, &mut rng);
        let p = inst.problem();
        let (_, bucket_s) = rt.spar_gw_bucket(GroundCost::L2, n).unwrap();
        let sampler = GwSampler::new(p.a, p.b, 0.0);
        let set = sampler.sample_iid(&mut rng, bucket_s);
        rt.run_spar_gw(GroundCost::L2, &inst.cx, &inst.cy, &inst.a, &inst.b, &set).unwrap();
    }
    let (compiled, cached, execs) = rt.stats();
    assert_eq!(execs, 3);
    assert_eq!(compiled, 1, "expected one compilation, got {compiled}");
    assert_eq!(cached, 1);
}

#[test]
fn pjrt_l1_artifact_runs() {
    // The indecomposable-cost artifact is the paper's differentiator; it
    // must execute, not just the ℓ2 one.
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let n = 28;
    let mut rng = Xoshiro256::new(23);
    let inst = Workload::Moon.make(n, &mut rng);
    let p = inst.problem();
    let (_, bucket_s) = rt.spar_gw_bucket(GroundCost::L1, n).expect("l1 bucket");
    let sampler = GwSampler::new(p.a, p.b, 0.0);
    let set = sampler.sample_iid(&mut rng, bucket_s);
    let out = rt.run_spar_gw(GroundCost::L1, &inst.cx, &inst.cy, &inst.a, &inst.b, &set).unwrap();
    assert!(out.gw.is_finite() && out.gw >= -1e-6, "l1 gw {}", out.gw);
}

#[test]
fn oversized_problem_is_rejected_cleanly() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let m = Manifest::load(&dir).unwrap();
    let max_bucket = m.spar_buckets(GroundCost::L2).into_iter().max().unwrap();
    let n = max_bucket + 1;
    assert!(rt.spar_gw_bucket(GroundCost::L2, n).is_none());
    let mut rng = Xoshiro256::new(24);
    let inst = Workload::Moon.make(n, &mut rng);
    let p = inst.problem();
    let sampler = GwSampler::new(p.a, p.b, 0.0);
    let set = sampler.sample_iid(&mut rng, 8);
    let res = rt.run_spar_gw(GroundCost::L2, &inst.cx, &inst.cy, &inst.a, &inst.b, &set);
    let err = match res {
        Ok(_) => panic!("oversized problem unexpectedly succeeded"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("bucket"), "unexpected error: {err:#}");
}
