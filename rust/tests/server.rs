//! Serve-mode integration tests: the newline-framed protocol end to end
//! over in-process socket pairs — response framing, warm-cache behaviour
//! across requests, bit-identity of streamed rows against a batch Gram
//! run, and the graceful-drain contract (in-flight requests finish,
//! post-drain requests are refused).

#![cfg(unix)]

use std::io::{BufRead, BufReader, Read, Write};
use std::net::Shutdown;
use std::os::unix::net::UnixStream;
use std::sync::Arc;

use spargw::coordinator::engine::{EngineConfig, PairwiseEngine};
use spargw::coordinator::service::PairwiseConfig;
use spargw::datasets::graphsets;
use spargw::server::{serve_connection, serve_socket, ServeOptions, ServerState};

const SEED: u64 = 11;

/// Fast-but-nontrivial solver settings, the determinism suite's toy
/// shape.
fn config() -> PairwiseConfig {
    let mut cfg = PairwiseConfig {
        solver: "spar_gw".to_string(),
        workers: 2,
        seed: SEED,
        ..Default::default()
    };
    cfg.spar.sample_size = 384;
    cfg.spar.outer_iters = 4;
    cfg.spar.inner_iters = 8;
    cfg
}

/// Spawn a serve loop over one end of a socket pair; returns the client
/// stream and the join handle yielding the connection's outcome.
fn spawn_serve(
    state: &Arc<ServerState>,
) -> (UnixStream, std::thread::JoinHandle<spargw::server::ServeOutcome>) {
    let (client, server_io) = UnixStream::pair().expect("socketpair");
    let read_half = server_io.try_clone().expect("clone server stream");
    let state = Arc::clone(state);
    let handle = std::thread::spawn(move || {
        serve_connection(&state, read_half, server_io).expect("serve connection")
    });
    (client, handle)
}

fn send(client: &UnixStream, line: &str) {
    let mut w = client;
    w.write_all(format!("{line}\n").as_bytes()).expect("send request");
}

/// Read one framed response: the status line plus, for `ok`, exactly the
/// advertised payload lines.
fn read_block(resp: &mut BufReader<UnixStream>) -> (String, Vec<String>) {
    let mut head = String::new();
    resp.read_line(&mut head).expect("response head");
    let head = head.trim_end().to_string();
    let mut payload = Vec::new();
    if let Some(rest) = head.strip_prefix("ok ") {
        let n: usize = rest
            .split_whitespace()
            .find_map(|t| t.strip_prefix("lines="))
            .expect("lines= token")
            .parse()
            .expect("lines= count");
        for _ in 0..n {
            let mut line = String::new();
            resp.read_line(&mut line).expect("payload line");
            payload.push(line.trim_end().to_string());
        }
    }
    (head, payload)
}

/// Extract `(i, j, value_bits)` from the `pair` rows of a payload.
fn pair_rows(payload: &[String]) -> Vec<(usize, usize, u64)> {
    payload
        .iter()
        .filter(|l| l.starts_with("pair "))
        .map(|l| {
            let t: Vec<&str> = l.split_whitespace().collect();
            (
                t[2].parse().expect("i"),
                t[3].parse().expect("j"),
                u64::from_str_radix(t[4], 16).expect("hex bits"),
            )
        })
        .collect()
}

fn cache_line(payload: &[String]) -> &str {
    payload
        .iter()
        .find(|l| l.starts_with("# cache "))
        .expect("trailing # cache line")
}

#[test]
fn serve_rounds_are_bit_identical_to_batch_and_second_round_is_warm() {
    let cfg = config();
    let state = Arc::new(ServerState::new(cfg.clone(), ServeOptions::default()));
    let (client, handle) = spawn_serve(&state);
    let mut resp = BufReader::new(client.try_clone().expect("clone client"));

    // Round 1: cold — every structure is built.
    send(&client, "pairwise synthetic:6");
    let (ok1, block1) = read_block(&mut resp);
    assert!(ok1.starts_with("ok 1 lines="), "{ok1}");
    let c1 = cache_line(&block1);
    assert!(c1.contains("structures=6"), "{c1}");
    assert!(c1.contains("built=6"), "{c1}");
    assert!(c1.contains("hits=0"), "{c1}");

    // Round 2: identical request — served entirely from the warm cache
    // (hits == structures, built == 0), rows byte-identical to round 1.
    send(&client, "pairwise synthetic:6");
    let (ok2, block2) = read_block(&mut resp);
    assert!(ok2.starts_with("ok 2 lines="), "{ok2}");
    let c2 = cache_line(&block2);
    assert!(c2.contains("built=0"), "second round must rebuild nothing: {c2}");
    assert!(c2.contains("hits=6"), "{c2}");

    // Single-pair verb, indices deliberately reversed: the response must
    // be the canonical (1, 4) row.
    send(&client, "solve synthetic:6 4 1");
    let (ok3, block3) = read_block(&mut resp);
    assert!(ok3.starts_with("ok 3 lines="), "{ok3}");

    send(&client, "status");
    let (ok4, block4) = read_block(&mut resp);
    assert!(ok4.starts_with("ok 4 lines="), "{ok4}");
    assert!(
        block4.iter().any(|l| l.starts_with("# server served=3 ")),
        "{block4:?}"
    );
    assert!(block4.iter().any(|l| l.starts_with("# metrics ")), "{block4:?}");

    // Drain, then one more request: refused, not queued.
    send(&client, "drain");
    let (ack, _) = read_block(&mut resp);
    assert_eq!(ack, "draining 5");
    send(&client, "pairwise synthetic:6");
    let (refused, _) = read_block(&mut resp);
    assert_eq!(refused, "draining 6");
    client.shutdown(Shutdown::Write).expect("shutdown write");

    let outcome = handle.join().expect("serve thread");
    assert_eq!(outcome.served, 4);
    assert_eq!(outcome.refused, 1);
    assert_eq!(outcome.errors, 0);

    // Bit-identity: every streamed row must carry exactly the bits a
    // batch Gram run computes for the same config/seed/dataset.
    let ds = graphsets::by_name("synthetic:6", SEED).expect("dataset");
    let eng = PairwiseEngine::new(cfg, EngineConfig::default());
    let g = eng.gram(&ds).expect("batch gram");
    let rows1 = pair_rows(&block1);
    assert_eq!(rows1.len(), 15, "6 graphs give 15 upper-triangular pairs");
    for &(i, j, bits) in &rows1 {
        assert_eq!(
            bits,
            g.distances[(i, j)].to_bits(),
            "serve row ({i},{j}) diverged from batch"
        );
    }
    assert_eq!(rows1, pair_rows(&block2), "warm round changed bits");
    let rows3 = pair_rows(&block3);
    assert_eq!(rows3, vec![(1, 4, g.distances[(1, 4)].to_bits())]);
}

#[test]
fn panicking_request_is_isolated_and_the_server_keeps_serving() {
    let cfg = config();
    let state = Arc::new(ServerState::new(cfg.clone(), ServeOptions::default()));
    let (client, server_io) = UnixStream::pair().expect("socketpair");
    let read_half = server_io.try_clone().expect("clone server stream");
    let handle = {
        let state = Arc::clone(&state);
        std::thread::spawn(move || {
            // The executor runs on the serve_connection caller's thread,
            // so a thread-local fault armed here reaches it: the first
            // request panics mid-execution.
            spargw::util::fault::with_fault("serve.execute:1:panic", || {
                serve_connection(&state, read_half, server_io).expect("serve connection")
            })
        })
    };
    let mut resp = BufReader::new(client.try_clone().expect("clone client"));

    // Request 1 hits the injected panic: an `err` response naming the
    // panic, not a dead connection.
    send(&client, "pairwise synthetic:4");
    let (head1, _) = read_block(&mut resp);
    assert!(head1.starts_with("err 1 "), "{head1}");
    assert!(head1.contains("panicked"), "{head1}");
    assert!(head1.contains("serve.execute"), "{head1}");

    // Request 2 is served normally on the same connection.
    send(&client, "pairwise synthetic:4");
    let (head2, block2) = read_block(&mut resp);
    assert!(head2.starts_with("ok 2 lines="), "{head2}");
    client.shutdown(Shutdown::Write).expect("shutdown write");
    let outcome = handle.join().expect("serve thread");
    assert_eq!(outcome.served, 1);
    assert_eq!(outcome.errors, 1);
    assert_eq!(outcome.refused, 0);

    // The post-panic response is bit-identical to a batch Gram run: the
    // replaced workspace and recovered cache leak nothing into results.
    let ds = graphsets::by_name("synthetic:4", SEED).expect("dataset");
    let eng = PairwiseEngine::new(cfg, EngineConfig::default());
    let g = eng.gram(&ds).expect("batch gram");
    let rows = pair_rows(&block2);
    assert_eq!(rows.len(), 6, "4 graphs give 6 upper-triangular pairs");
    for (i, j, bits) in rows {
        assert_eq!(
            bits,
            g.distances[(i, j)].to_bits(),
            "post-panic row ({i},{j}) diverged from batch"
        );
    }
}

#[test]
fn drain_finishes_in_flight_and_refuses_new_requests() {
    let state = Arc::new(ServerState::new(config(), ServeOptions::default()));
    let (client, handle) = spawn_serve(&state);

    // Pipeline everything without reading: a malformed request, a
    // compute request, the drain, and a post-drain request. The reader
    // admits strictly in order, so the compute job is in flight when the
    // drain begins and the last request arrives after it.
    (&client)
        .write_all(b"bogus\npairwise synthetic:4\ndrain\npairwise synthetic:4\n")
        .expect("send requests");
    client.shutdown(Shutdown::Write).expect("shutdown write");

    let mut all = String::new();
    BufReader::new(client)
        .read_to_string(&mut all)
        .expect("read responses");
    let outcome = handle.join().expect("serve thread");

    assert_eq!(outcome.served, 1, "the in-flight request must finish\n{all}");
    assert_eq!(outcome.refused, 1, "{all}");
    assert_eq!(outcome.errors, 1, "{all}");
    assert!(all.contains("err 1 "), "{all}");
    // The admitted compute request completed despite the drain: its full
    // sink block (rows + done marker) is on the wire.
    assert!(all.contains("ok 2 lines="), "{all}");
    assert!(all.contains("\ndone 0\n"), "{all}");
    // Drain ack and the post-drain refusal.
    assert!(all.contains("draining 3"), "{all}");
    assert!(all.contains("draining 4"), "{all}");
}

#[test]
fn socket_mode_serves_and_cleans_up() {
    let sock = std::env::temp_dir().join(format!(
        "spargw-serve-test-{}.sock",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&sock);
    let state = Arc::new(ServerState::new(config(), ServeOptions::default()));
    let handle = {
        let state = Arc::clone(&state);
        let sock = sock.clone();
        std::thread::spawn(move || serve_socket(&state, &sock).expect("serve socket"))
    };

    // The listener binds asynchronously; retry the connect briefly.
    let client = {
        let mut tries = 0;
        loop {
            match UnixStream::connect(&sock) {
                Ok(c) => break c,
                Err(_) => {
                    tries += 1;
                    assert!(tries < 500, "socket never came up at {}", sock.display());
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
            }
        }
    };
    (&client).write_all(b"status\ndrain\n").expect("send requests");
    client.shutdown(Shutdown::Write).expect("shutdown write");
    let mut all = String::new();
    BufReader::new(client).read_to_string(&mut all).expect("read responses");

    let outcome = handle.join().expect("socket serve thread");
    assert_eq!(outcome.served, 1, "{all}");
    assert!(all.contains("# server "), "{all}");
    assert!(all.contains("draining 2"), "{all}");
    assert!(!sock.exists(), "socket file must be removed after drain");
}
