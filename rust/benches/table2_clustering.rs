//! **Table 2** — graph clustering: pairwise (F)GW matrix → similarity
//! `exp(−D/γ)` → spectral clustering → Rand index (%), ten random
//! initializations, γ cross-validated over powers of two.
//!
//! Methods (as in the paper's table): EGW, S-GWL, LR-GW, AE (ℓ1/ℓ2),
//! SaGroW (ℓ1/ℓ2), Spar-GW (ℓ1/ℓ2).
//!
//! Output: the table on stdout + `results/table2.csv`.

use spargw::bench::workloads::{full_mode, smoke_mode};
use spargw::bench::{pairwise_distances, Method, RunSettings};
use spargw::coordinator::service::similarity_from_distances;
use spargw::datasets::graphsets::all_datasets;
use spargw::gw::GroundCost;
use spargw::ml::{rand_index, spectral_clustering};
use spargw::rng::{derive_seed, Xoshiro256};
use spargw::util::csv::CsvWriter;
use spargw::util::{mean, std_dev};

/// Best mean RI over the γ grid, with its std-dev over ten inits.
fn cluster_score(d: &spargw::linalg::Mat, labels: &[usize], k: usize, seed: u64) -> (f64, f64) {
    let gammas: Vec<f64> = (-10..=10).step_by(2).map(|e| 2f64.powi(e)).collect();
    let mut best = (f64::NEG_INFINITY, 0.0);
    for &gamma in &gammas {
        let sim = similarity_from_distances(d, gamma);
        let mut ris = Vec::new();
        for rep in 0..10u64 {
            let mut rng = Xoshiro256::new(derive_seed(seed, rep));
            ris.push(rand_index(&spectral_clustering(&sim, k, &mut rng), labels));
        }
        let (m, sd) = (mean(&ris), std_dev(&ris));
        if m > best.0 {
            best = (m, sd);
        }
    }
    best
}

fn main() {
    let seed = 7u64;
    let workers = 4;
    let mut datasets = all_datasets(seed);
    if !full_mode() {
        // Keep the harness on budget: trim the largest datasets.
        for ds in &mut datasets {
            let cap = if smoke_mode() {
                8
            } else if ds.mean_nodes() > 50.0 {
                12
            } else {
                20
            };
            ds.graphs.truncate(cap);
        }
    }

    // (method, cost) rows of the paper's Table 2.
    let rows: Vec<(Method, GroundCost)> = vec![
        (Method::Egw, GroundCost::L2),
        (Method::Sgwl, GroundCost::L2),
        (Method::LrGw, GroundCost::L2),
        (Method::Anchor, GroundCost::L2),
        (Method::Anchor, GroundCost::L1),
        (Method::Sagrow, GroundCost::L2),
        (Method::Sagrow, GroundCost::L1),
        (Method::SparGw, GroundCost::L2),
        (Method::SparGw, GroundCost::L1),
    ];

    let mut csv =
        CsvWriter::create("results/table2.csv", &["method", "cost", "dataset", "ri_mean", "ri_sd"])
            .expect("csv");

    print!("{:<22}", "method");
    for ds in &datasets {
        print!(" {:>12}", ds.name);
    }
    println!();

    for (method, cost) in rows {
        print!("{:<22}", format!("{} ({})", method.name(), cost.name()));
        for ds in &datasets {
            let st = RunSettings::default();
            let d = pairwise_distances(ds, method, cost, &st, workers, seed);
            let (ri, sd) = cluster_score(&d, &ds.labels(), ds.n_classes, seed ^ 0xC1);
            print!(" {:>7.2}±{:<4.2}", 100.0 * ri, 100.0 * sd);
            csv.row(&[
                method.name().into(),
                cost.name().into(),
                ds.name.into(),
                format!("{:.4}", 100.0 * ri),
                format!("{:.4}", 100.0 * sd),
            ])
            .unwrap();
        }
        println!();
    }
    csv.flush().unwrap();
    println!("\nwrote results/table2.csv");
}
