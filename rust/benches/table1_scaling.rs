//! **Table 1** — empirical time-complexity check: run each registered
//! solver over a geometric n-sweep, fit the log-log slope of CPU time vs
//! n, and print it next to the complexity exponent the paper's Table 1
//! claims.
//!
//! The row list is generated from [`SolverRegistry::names`] — every
//! engine constructible by name gets a row (in the regime its Table-1 row
//! assumes: decomposable ℓ2 for EGW/LR-GW/S-GWL), followed by contrast
//! rows built through the same registry: Spar-GW under the indecomposable
//! ℓ1 cost (where its advantage is the whole point), dense EGW under ℓ1
//! (the O(n⁴) generic-tensor path), and Spar-GW with the row-chunked
//! threaded cost kernel.
//!
//! After the fitted table, the **million-point tier** section times the
//! hierarchical solvers from raw point clouds and records the solve-path
//! peak allocation (counting global allocator): qgw streams the points
//! and never allocates O(n²), while the dense baselines are capped at the
//! largest n whose relation matrices fit. Rows land in
//! `results/BENCH_scaling.json`, mirrored to the repository root (the
//! tracked perf-trajectory snapshot).
//!
//! Output: the fitted table on stdout + `results/table1.csv` +
//! `results/BENCH_scaling.json`.

use std::collections::BTreeMap;
use std::time::Instant;

use spargw::bench::workloads::{full_mode, Workload};
use spargw::bench::{peak_bytes_during, CountingAllocator};
use spargw::datasets::moon::moon_points;
use spargw::datasets::pairwise_euclidean;
use spargw::gw::core::Workspace;
use spargw::gw::solver::{SolverBase, SolverRegistry};
use spargw::gw::{qgw, GroundCost, GwProblem, PointCloud};
use spargw::rng::{derive_seed, Xoshiro256};
use spargw::util::csv::CsvWriter;
use spargw::util::uniform;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Least-squares slope of log(time) against log(n).
fn loglog_slope(ns: &[usize], ts: &[f64]) -> f64 {
    let xs: Vec<f64> = ns.iter().map(|&n| (n as f64).ln()).collect();
    let ys: Vec<f64> = ts.iter().map(|&t| t.max(1e-9).ln()).collect();
    let mx = xs.iter().sum::<f64>() / xs.len() as f64;
    let my = ys.iter().sum::<f64>() / ys.len() as f64;
    let num: f64 = xs.iter().zip(&ys).map(|(&x, &y)| (x - mx) * (y - my)).sum();
    let den: f64 = xs.iter().map(|&x| (x - mx) * (x - mx)).sum();
    num / den
}

/// The complexity claim of each registry entry's Table-1 row (all rows
/// run the decomposable ℓ2 regime; ℓ1 contrast rows are added below).
fn paper_claim(name: &str) -> &'static str {
    match name {
        "spar_gw" => "n^2 + s^2, s = 16n",
        "spar_fgw" => "n^2 + s^2 (fused; α=1 on plain GW)",
        "spar_ugw" => "mn + s^2 (unbalanced)",
        "egw" => "n^3 (decomposable)",
        "pga_gw" => "n^3 (decomposable)",
        "emd_gw" => "n^3 log n (LP inner)",
        "sagrow" => "n^2 (s'+log n)",
        "lr_gw" => "r(r+r)n (low-rank)",
        "sgwl" => "n^2 log n",
        "anchor" => "n^2 log(n^2)",
        "qgw" => "nm + solve(m), m = sqrt(n) (quantized)",
        other => panic!("no Table-1 claim recorded for solver {other:?}"),
    }
}

/// Time one registry-built solver over the n-sweep (Moon workload, same
/// instance seeds for every row).
fn sweep(
    name: &str,
    cost: GroundCost,
    opts: &BTreeMap<String, String>,
    ns: &[usize],
    ws: &mut Workspace,
) -> Vec<f64> {
    let base = SolverBase { cost, ..Default::default() };
    let solver =
        SolverRegistry::build_with_base(name, opts, &base).expect("registry build");
    let mut times = Vec::new();
    for (ni, &n) in ns.iter().enumerate() {
        let mut grng = Xoshiro256::new(derive_seed(0x7AB1, ni as u64));
        let inst = Workload::Moon.make(n, &mut grng);
        let p = inst.problem();
        let mut rng = Xoshiro256::new(derive_seed(29, n as u64));
        let t0 = Instant::now();
        let report = solver.solve(&p, &mut rng, ws).expect("solve");
        std::hint::black_box(report.value);
        times.push(t0.elapsed().as_secs_f64());
    }
    times
}

fn main() {
    let ns: Vec<usize> =
        if full_mode() { vec![64, 128, 256, 512] } else { vec![64, 128, 256] };
    println!("Table 1: empirical scaling exponents (n in {ns:?}, Moon workload)\n");
    println!(
        "{:<12} {:<5} {:>10} {:>22}   {}",
        "solver", "cost", "slope", "time/n (s)", "paper claim"
    );

    // Registry rows + ℓ1/pool-width contrast rows, all built by name.
    // The serial rows pin the pool width to 1; the `-t4` row lifts the
    // cap to 4 so the chunked kernels engage (same bits either way).
    let no_opts = BTreeMap::new();
    let mut rows: Vec<(&str, GroundCost, &BTreeMap<String, String>, &str, String, usize)> =
        Vec::new();
    for &name in SolverRegistry::names() {
        rows.push((name, GroundCost::L2, &no_opts, paper_claim(name), name.to_string(), 1));
    }
    rows.push((
        "spar_gw",
        GroundCost::L1,
        &no_opts,
        "n^2 + s^2 (arbitrary L)",
        "spar_gw".to_string(),
        1,
    ));
    rows.push((
        "egw",
        GroundCost::L1,
        &no_opts,
        "n^4 (no decomposition)",
        "egw".to_string(),
        1,
    ));
    rows.push((
        "spar_gw",
        GroundCost::L1,
        &no_opts,
        "n^2 + s^2/t (pool, 4 threads)",
        "spar_gw-t4".to_string(),
        4,
    ));

    let mut csv =
        CsvWriter::create("results/table1.csv", &["method", "cost", "n", "seconds", "slope"])
            .expect("csv");
    let mut ws = Workspace::new();

    for (name, cost, opts, claim, label, width) in rows {
        // The generic-tensor dense path is O(n^4): cap its sweep so the
        // bench terminates (slope fits on the smaller prefix).
        let ns_m: Vec<usize> = if name == "egw" && cost == GroundCost::L1 {
            ns.iter().copied().filter(|&n| n <= 128).collect()
        } else {
            ns.clone()
        };
        let times = spargw::runtime::pool::with_thread_limit(width, || {
            sweep(name, cost, opts, &ns_m, &mut ws)
        });
        let slope = loglog_slope(&ns_m, &times);
        let times_str: Vec<String> = times.iter().map(|t| format!("{t:.3}")).collect();
        println!(
            "{:<12} {:<5} {:>10.2} {:>22}   {}",
            label,
            cost.name(),
            slope,
            times_str.join("/"),
            claim
        );
        for (i, &n) in ns_m.iter().enumerate() {
            csv.row(&[
                label.clone(),
                cost.name().into(),
                n.to_string(),
                format!("{:.6e}", times[i]),
                format!("{slope:.3}"),
            ])
            .unwrap();
        }
    }

    csv.flush().unwrap();
    println!("\nwrote results/table1.csv");

    // ------------------------------------------------------------------
    // Million-point tier: seconds + solve-path peak bytes from raw point
    // clouds. qgw consumes the points directly (no n×n matrix anywhere);
    // the dense baselines (spar_gw, factored lr_gw) get their relation
    // matrices materialized *outside* the measured region and are capped
    // at the largest n whose dense inputs fit, so the recorded peak is
    // the solve path's own allocation in every row.
    // ------------------------------------------------------------------
    let tier_ns: Vec<usize> =
        if full_mode() { vec![2_000, 10_000, 50_000] } else { vec![256, 512] };
    let dense_cap: usize = if full_mode() { 2_000 } else { 512 };
    let tier_base = SolverBase { outer_iters: 5, ..Default::default() };
    println!(
        "\nMillion-point tier (moon points, uniform marginals, outer = {}):",
        tier_base.outer_iters
    );
    println!(
        "{:<10} {:>8} {:>12} {:>14}",
        "solver", "n", "seconds", "peak_bytes"
    );
    let mut tier_rows: Vec<(String, usize, f64, usize)> = Vec::new();
    for (ti, &n) in tier_ns.iter().enumerate() {
        let mut grng = Xoshiro256::new(derive_seed(0x5CA1, ti as u64));
        let (src, tgt) = moon_points(n, 0.05, &mut grng);
        let a = uniform(n);

        // qgw over the implicit point-cloud relation.
        let qsolver = qgw::build(&BTreeMap::new(), &tier_base).expect("qgw build");
        let px = PointCloud::from_points(&src);
        let py = PointCloud::from_points(&tgt);
        let t0 = Instant::now();
        let (rep, peak) = peak_bytes_during(|| {
            let mut rng = Xoshiro256::new(derive_seed(31, n as u64));
            qsolver.solve_points(&px, &py, &a, &a, &mut rng, &mut ws).expect("qgw solve")
        });
        let secs = t0.elapsed().as_secs_f64();
        std::hint::black_box(rep.value);
        println!("{:<10} {n:>8} {secs:>12.4} {peak:>14}", "qgw");
        tier_rows.push(("qgw".to_string(), n, secs, peak));

        if n > dense_cap {
            continue;
        }
        let cx = pairwise_euclidean(&src);
        let cy = pairwise_euclidean(&tgt);
        let p = GwProblem::new(&cx, &cy, &a, &a);
        // Factored lr_gw keeps the paper rank but a Nyström operator and
        // a short descent so the row times the factored path, not the
        // schedule length.
        let mut lr_opts = BTreeMap::new();
        lr_opts.insert("outer".to_string(), "10".to_string());
        lr_opts.insert("landmarks".to_string(), "64".to_string());
        let no_tier_opts = BTreeMap::new();
        for (name, opts) in [("spar_gw", &no_tier_opts), ("lr_gw", &lr_opts)] {
            let solver =
                SolverRegistry::build_with_base(name, opts, &tier_base).expect("tier build");
            let t0 = Instant::now();
            let (rep, peak) = peak_bytes_during(|| {
                let mut rng = Xoshiro256::new(derive_seed(31, n as u64));
                solver.solve(&p, &mut rng, &mut ws).expect("tier solve")
            });
            let secs = t0.elapsed().as_secs_f64();
            std::hint::black_box(rep.value);
            println!("{name:<10} {n:>8} {secs:>12.4} {peak:>14}");
            tier_rows.push((name.to_string(), n, secs, peak));
        }
    }

    // Emit BENCH_scaling.json: results/ for the CI artifact upload plus a
    // mirror at the repository root (the tracked snapshot the acceptance
    // gates read — same convention as BENCH_threads/BENCH_kernels).
    let tier_ns_str: Vec<String> = tier_ns.iter().map(|n| n.to_string()).collect();
    let mut sjson = String::from("{\n");
    sjson.push_str(&format!(
        "  \"workload\": \"moon-points\",\n  \"full\": {},\n  \"dense_cap\": {dense_cap},\n  \
         \"tier_ns\": [{}],\n  \"rows\": [\n",
        full_mode(),
        tier_ns_str.join(", ")
    ));
    for (i, (name, n, secs, peak)) in tier_rows.iter().enumerate() {
        sjson.push_str(&format!(
            "    {{\"solver\": \"{name}\", \"n\": {n}, \"seconds\": {secs:.6e}, \
             \"peak_bytes\": {peak}}}{}\n",
            if i + 1 < tier_rows.len() { "," } else { "" }
        ));
    }
    sjson.push_str("  ]\n}\n");
    let write_artifact = |name: &str, contents: &str| {
        let local = format!("results/{name}");
        std::fs::write(&local, contents).unwrap_or_else(|e| panic!("write {local}: {e}"));
        println!("wrote {local}");
        if let Some(root) = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent() {
            let rp = root.join(name);
            match std::fs::write(&rp, contents) {
                Ok(()) => println!("wrote {}", rp.display()),
                Err(e) => println!("WARNING: cannot write {}: {e}", rp.display()),
            }
        }
    };
    write_artifact("BENCH_scaling.json", &sjson);
}
