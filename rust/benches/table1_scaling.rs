//! **Table 1** — empirical time-complexity check: run each method over a
//! geometric n-sweep, fit the log-log slope of CPU time vs n, and print
//! it next to the complexity exponent the paper's Table 1 claims.
//!
//! Methods are run in the regime their Table-1 row assumes (decomposable
//! ℓ2 for EGW/LR-GW/S-GWL; Spar-GW is additionally measured under the
//! indecomposable ℓ1 cost, where its advantage is the whole point).
//!
//! Output: the fitted table on stdout + `results/table1.csv`.

use spargw::bench::workloads::{full_mode, Workload};
use spargw::bench::{Method, RunSettings};
use spargw::gw::core::Workspace;
use spargw::gw::sampling::GwSampler;
use spargw::gw::spar_gw::{spar_gw_with_workspace, SparGwConfig};
use spargw::gw::GroundCost;
use spargw::rng::{derive_seed, Xoshiro256};
use spargw::util::csv::CsvWriter;

/// Least-squares slope of log(time) against log(n).
fn loglog_slope(ns: &[usize], ts: &[f64]) -> f64 {
    let xs: Vec<f64> = ns.iter().map(|&n| (n as f64).ln()).collect();
    let ys: Vec<f64> = ts.iter().map(|&t| t.max(1e-9).ln()).collect();
    let mx = xs.iter().sum::<f64>() / xs.len() as f64;
    let my = ys.iter().sum::<f64>() / ys.len() as f64;
    let num: f64 = xs.iter().zip(&ys).map(|(&x, &y)| (x - mx) * (y - my)).sum();
    let den: f64 = xs.iter().map(|&x| (x - mx) * (x - mx)).sum();
    num / den
}

fn main() {
    let ns: Vec<usize> =
        if full_mode() { vec![64, 128, 256, 512] } else { vec![64, 128, 256] };
    println!("Table 1: empirical scaling exponents (n in {ns:?}, Moon workload)\n");
    println!(
        "{:<10} {:<5} {:>10} {:>22}   {}",
        "method", "cost", "slope", "time/n (s)", "paper claim"
    );

    let rows: Vec<(Method, GroundCost, &str)> = vec![
        (Method::Egw, GroundCost::L2, "n^3 (decomposable)"),
        (Method::PgaGw, GroundCost::L2, "n^3 (decomposable)"),
        (Method::EmdGw, GroundCost::L2, "n^3 log n (LP inner)"),
        (Method::Sgwl, GroundCost::L2, "n^2 log n"),
        (Method::LrGw, GroundCost::L2, "r(r+r)n (low-rank)"),
        (Method::Anchor, GroundCost::L2, "n^2 log(n^2)"),
        (Method::Sagrow, GroundCost::L2, "n^2 (s'+log n)"),
        (Method::SparGw, GroundCost::L2, "n^2 + s^2, s = 16n"),
        (Method::SparGw, GroundCost::L1, "n^2 + s^2 (arbitrary L)"),
        (Method::Egw, GroundCost::L1, "n^4 (no decomposition)"),
    ];

    let mut csv =
        CsvWriter::create("results/table1.csv", &["method", "cost", "n", "seconds", "slope"])
            .expect("csv");

    for (method, cost, claim) in rows {
        // The generic-tensor dense path is O(n^4): cap its sweep so the
        // bench terminates (slope fits on the smaller prefix).
        let ns_m: Vec<usize> = if method == Method::Egw && cost == GroundCost::L1 {
            ns.iter().copied().filter(|&n| n <= 128).collect()
        } else {
            ns.clone()
        };
        let mut times = Vec::new();
        for (ni, &n) in ns_m.iter().enumerate() {
            let mut grng = Xoshiro256::new(derive_seed(0x7AB1, ni as u64));
            let inst = Workload::Moon.make(n, &mut grng);
            let p = inst.problem();
            let st = RunSettings::default();
            let mut rng = Xoshiro256::new(derive_seed(29, n as u64));
            let out = method.run(&p, None, cost, &st, &mut rng).unwrap();
            times.push(out.seconds);
        }
        let slope = loglog_slope(&ns_m, &times);
        let times_str: Vec<String> = times.iter().map(|t| format!("{t:.3}")).collect();
        println!(
            "{:<10} {:<5} {:>10.2} {:>22}   {}",
            method.name(),
            cost.name(),
            slope,
            times_str.join("/"),
            claim
        );
        for (i, &n) in ns_m.iter().enumerate() {
            csv.row(&[
                method.name().into(),
                cost.name().into(),
                n.to_string(),
                format!("{:.6e}", times[i]),
                format!("{slope:.3}"),
            ])
            .unwrap();
        }
    }
    // Extra row (not a paper column): Spar-GW with the SparCore engine's
    // row-chunked cost kernel and a reused workspace — the coordinator's
    // few-large-pairs configuration. Same estimates as the serial row
    // (threading is bit-transparent), lower wall time once s² dominates.
    let threads = 4;
    let mut ws = Workspace::new();
    let mut times = Vec::new();
    for (ni, &n) in ns.iter().enumerate() {
        let mut grng = Xoshiro256::new(derive_seed(0x7AB1, ni as u64));
        let inst = Workload::Moon.make(n, &mut grng);
        let p = inst.problem();
        let mut rng = Xoshiro256::new(derive_seed(29, n as u64));
        let mut sampler = GwSampler::new(p.a, p.b, 0.0);
        let set = sampler.sample_iid(&mut rng, 16 * n);
        let cfg = SparGwConfig { sample_size: 16 * n, ..Default::default() };
        let t0 = std::time::Instant::now();
        let out = spar_gw_with_workspace(&p, GroundCost::L1, &cfg, &set, &mut ws, threads);
        let secs = t0.elapsed().as_secs_f64();
        std::hint::black_box(out.value);
        times.push(secs);
    }
    let slope = loglog_slope(&ns, &times);
    let times_str: Vec<String> = times.iter().map(|t| format!("{t:.3}")).collect();
    println!(
        "{:<10} {:<5} {:>10.2} {:>22}   {}",
        format!("Spar-GW×{threads}"),
        "l1",
        slope,
        times_str.join("/"),
        "n^2 + s^2/t (row-chunked)"
    );
    for (i, &n) in ns.iter().enumerate() {
        csv.row(&[
            format!("Spar-GW-t{threads}"),
            "l1".into(),
            n.to_string(),
            format!("{:.6e}", times[i]),
            format!("{slope:.3}"),
        ])
        .unwrap();
    }

    csv.flush().unwrap();
    println!("\nwrote results/table1.csv");
}
