//! **Table 1** — empirical time-complexity check: run each registered
//! solver over a geometric n-sweep, fit the log-log slope of CPU time vs
//! n, and print it next to the complexity exponent the paper's Table 1
//! claims.
//!
//! The row list is generated from [`SolverRegistry::names`] — every
//! engine constructible by name gets a row (in the regime its Table-1 row
//! assumes: decomposable ℓ2 for EGW/LR-GW/S-GWL), followed by contrast
//! rows built through the same registry: Spar-GW under the indecomposable
//! ℓ1 cost (where its advantage is the whole point), dense EGW under ℓ1
//! (the O(n⁴) generic-tensor path), and Spar-GW with the row-chunked
//! threaded cost kernel.
//!
//! Output: the fitted table on stdout + `results/table1.csv`.

use std::collections::BTreeMap;
use std::time::Instant;

use spargw::bench::workloads::{full_mode, Workload};
use spargw::gw::core::Workspace;
use spargw::gw::solver::{SolverBase, SolverRegistry};
use spargw::gw::GroundCost;
use spargw::rng::{derive_seed, Xoshiro256};
use spargw::util::csv::CsvWriter;

/// Least-squares slope of log(time) against log(n).
fn loglog_slope(ns: &[usize], ts: &[f64]) -> f64 {
    let xs: Vec<f64> = ns.iter().map(|&n| (n as f64).ln()).collect();
    let ys: Vec<f64> = ts.iter().map(|&t| t.max(1e-9).ln()).collect();
    let mx = xs.iter().sum::<f64>() / xs.len() as f64;
    let my = ys.iter().sum::<f64>() / ys.len() as f64;
    let num: f64 = xs.iter().zip(&ys).map(|(&x, &y)| (x - mx) * (y - my)).sum();
    let den: f64 = xs.iter().map(|&x| (x - mx) * (x - mx)).sum();
    num / den
}

/// The complexity claim of each registry entry's Table-1 row (all rows
/// run the decomposable ℓ2 regime; ℓ1 contrast rows are added below).
fn paper_claim(name: &str) -> &'static str {
    match name {
        "spar_gw" => "n^2 + s^2, s = 16n",
        "spar_fgw" => "n^2 + s^2 (fused; α=1 on plain GW)",
        "spar_ugw" => "mn + s^2 (unbalanced)",
        "egw" => "n^3 (decomposable)",
        "pga_gw" => "n^3 (decomposable)",
        "emd_gw" => "n^3 log n (LP inner)",
        "sagrow" => "n^2 (s'+log n)",
        "lr_gw" => "r(r+r)n (low-rank)",
        "sgwl" => "n^2 log n",
        "anchor" => "n^2 log(n^2)",
        other => panic!("no Table-1 claim recorded for solver {other:?}"),
    }
}

/// Time one registry-built solver over the n-sweep (Moon workload, same
/// instance seeds for every row).
fn sweep(
    name: &str,
    cost: GroundCost,
    opts: &BTreeMap<String, String>,
    ns: &[usize],
    ws: &mut Workspace,
) -> Vec<f64> {
    let base = SolverBase { cost, ..Default::default() };
    let solver =
        SolverRegistry::build_with_base(name, opts, &base).expect("registry build");
    let mut times = Vec::new();
    for (ni, &n) in ns.iter().enumerate() {
        let mut grng = Xoshiro256::new(derive_seed(0x7AB1, ni as u64));
        let inst = Workload::Moon.make(n, &mut grng);
        let p = inst.problem();
        let mut rng = Xoshiro256::new(derive_seed(29, n as u64));
        let t0 = Instant::now();
        let report = solver.solve(&p, &mut rng, ws).expect("solve");
        std::hint::black_box(report.value);
        times.push(t0.elapsed().as_secs_f64());
    }
    times
}

fn main() {
    let ns: Vec<usize> =
        if full_mode() { vec![64, 128, 256, 512] } else { vec![64, 128, 256] };
    println!("Table 1: empirical scaling exponents (n in {ns:?}, Moon workload)\n");
    println!(
        "{:<12} {:<5} {:>10} {:>22}   {}",
        "solver", "cost", "slope", "time/n (s)", "paper claim"
    );

    // Registry rows + ℓ1/pool-width contrast rows, all built by name.
    // The serial rows pin the pool width to 1; the `-t4` row lifts the
    // cap to 4 so the chunked kernels engage (same bits either way).
    let no_opts = BTreeMap::new();
    let mut rows: Vec<(&str, GroundCost, &BTreeMap<String, String>, &str, String, usize)> =
        Vec::new();
    for &name in SolverRegistry::names() {
        rows.push((name, GroundCost::L2, &no_opts, paper_claim(name), name.to_string(), 1));
    }
    rows.push((
        "spar_gw",
        GroundCost::L1,
        &no_opts,
        "n^2 + s^2 (arbitrary L)",
        "spar_gw".to_string(),
        1,
    ));
    rows.push((
        "egw",
        GroundCost::L1,
        &no_opts,
        "n^4 (no decomposition)",
        "egw".to_string(),
        1,
    ));
    rows.push((
        "spar_gw",
        GroundCost::L1,
        &no_opts,
        "n^2 + s^2/t (pool, 4 threads)",
        "spar_gw-t4".to_string(),
        4,
    ));

    let mut csv =
        CsvWriter::create("results/table1.csv", &["method", "cost", "n", "seconds", "slope"])
            .expect("csv");
    let mut ws = Workspace::new();

    for (name, cost, opts, claim, label, width) in rows {
        // The generic-tensor dense path is O(n^4): cap its sweep so the
        // bench terminates (slope fits on the smaller prefix).
        let ns_m: Vec<usize> = if name == "egw" && cost == GroundCost::L1 {
            ns.iter().copied().filter(|&n| n <= 128).collect()
        } else {
            ns.clone()
        };
        let times = spargw::runtime::pool::with_thread_limit(width, || {
            sweep(name, cost, opts, &ns_m, &mut ws)
        });
        let slope = loglog_slope(&ns_m, &times);
        let times_str: Vec<String> = times.iter().map(|t| format!("{t:.3}")).collect();
        println!(
            "{:<12} {:<5} {:>10.2} {:>22}   {}",
            label,
            cost.name(),
            slope,
            times_str.join("/"),
            claim
        );
        for (i, &n) in ns_m.iter().enumerate() {
            csv.row(&[
                label.clone(),
                cost.name().into(),
                n.to_string(),
                format!("{:.6e}", times[i]),
                format!("{slope:.3}"),
            ])
            .unwrap();
        }
    }

    csv.flush().unwrap();
    println!("\nwrote results/table1.csv");
}
