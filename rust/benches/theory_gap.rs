//! **Theorem 1 / Corollary 1 validation** — the stationarity gap
//! `G(T̃) = E(T̃) − min_{T′} ⟨∇E(T̃)/2 ⊙ …⟩` (computed exactly through the
//! transportation-simplex EMD solver) as the subsample size s grows and
//! as ε shrinks, plus the Poisson-sampling spectral-error bound of
//! Lemma 2.
//!
//! Expected shapes: G(T̃) decreases in s (the `√(n^{3−2α}/s)` term) and
//! decreases as ε → 0 (the `ε log n` term); the i.i.d. and Poisson
//! sampling schemes behave alike.
//!
//! Output: stdout series + `results/theory_gap.csv`.

use spargw::bench::workloads::Workload;
use spargw::gw::sampling::{sample_poisson, GwSampler};
use spargw::gw::spar_gw::{spar_gw_with_set, SparGwConfig};
use spargw::gw::stationarity::stationarity_gap_sparse;
use spargw::gw::GroundCost;
use spargw::rng::{derive_seed, Xoshiro256};
use spargw::util::csv::CsvWriter;
use spargw::util::{mean, std_dev};

fn main() {
    let n = 60; // exact-EMD inner solves bound the size
    let reps = 5;
    let mut grng = Xoshiro256::new(0x7E0);
    let inst = Workload::Moon.make(n, &mut grng);
    let p = inst.problem();
    let mut csv = CsvWriter::create(
        "results/theory_gap.csv",
        &["sweep", "param", "scheme", "gap_mean", "gap_sd"],
    )
    .expect("csv");

    println!("Theorem 1: stationarity gap G(T̃) on Moon, n = {n} (reps = {reps})\n");

    // --- Sweep 1: gap vs subsample size s at fixed ε. -------------------
    println!("{:<8} {:>8} {:>12} {:>12}  (eps = 0.01, iid sampling)", "sweep", "s", "gap_mean", "gap_sd");
    for &s_mult in &[2usize, 4, 8, 16, 32] {
        let s = s_mult * n;
        let mut gaps = Vec::new();
        for r in 0..reps {
            let mut rng = Xoshiro256::new(derive_seed(31, (s * 97 + r) as u64));
            let cfg = SparGwConfig { sample_size: s, epsilon: 0.01, ..Default::default() };
            let sampler = GwSampler::new(p.a, p.b, 0.0);
            let set = sampler.sample_iid(&mut rng, s);
            let res = spar_gw_with_set(&p, GroundCost::L2, &cfg, &set);
            gaps.push(stationarity_gap_sparse(&p, &res.plan, GroundCost::L2));
        }
        let (gm, gs) = (mean(&gaps), std_dev(&gaps));
        println!("{:<8} {:>7}n {:>12.4e} {:>12.4e}", "s", s_mult, gm, gs);
        csv.row(&["s".into(), s.to_string(), "iid".into(), format!("{gm:.6e}"), format!("{gs:.6e}")])
            .unwrap();
    }

    // --- Sweep 2: gap vs ε at fixed s = 16n (the ε·log n term). ---------
    println!("\n{:<8} {:>8} {:>12} {:>12}  (s = 16n, iid sampling)", "sweep", "eps", "gap_mean", "gap_sd");
    for &eps in &[1.0f64, 0.1, 0.01, 0.001] {
        let mut gaps = Vec::new();
        for r in 0..reps {
            let mut rng = Xoshiro256::new(derive_seed(37, (r as u64) ^ eps.to_bits()));
            let cfg = SparGwConfig { sample_size: 16 * n, epsilon: eps, ..Default::default() };
            let sampler = GwSampler::new(p.a, p.b, 0.0);
            let set = sampler.sample_iid(&mut rng, 16 * n);
            let res = spar_gw_with_set(&p, GroundCost::L2, &cfg, &set);
            gaps.push(stationarity_gap_sparse(&p, &res.plan, GroundCost::L2));
        }
        let (gm, gs) = (mean(&gaps), std_dev(&gaps));
        println!("{:<8} {:>8} {:>12.4e} {:>12.4e}", "eps", eps, gm, gs);
        csv.row(&["eps".into(), eps.to_string(), "iid".into(), format!("{gm:.6e}"), format!("{gs:.6e}")])
            .unwrap();
    }

    // --- Sweep 3: i.i.d. vs Poisson subsampling (Appendix B scheme). ----
    println!("\n{:<8} {:>8} {:>12} {:>12}  (eps = 0.01, s = 16n)", "sweep", "scheme", "gap_mean", "gap_sd");
    for scheme in ["iid", "poisson"] {
        let mut gaps = Vec::new();
        for r in 0..reps {
            let mut rng = Xoshiro256::new(derive_seed(41, r as u64));
            let cfg = SparGwConfig { sample_size: 16 * n, epsilon: 0.01, ..Default::default() };
            let set = if scheme == "iid" {
                let sampler = GwSampler::new(p.a, p.b, 0.0);
                sampler.sample_iid(&mut rng, 16 * n)
            } else {
                sample_poisson(&mut rng, p.a, p.b, 0.0, 16 * n)
            };
            let res = spar_gw_with_set(&p, GroundCost::L2, &cfg, &set);
            gaps.push(stationarity_gap_sparse(&p, &res.plan, GroundCost::L2));
        }
        let (gm, gs) = (mean(&gaps), std_dev(&gaps));
        println!("{:<8} {:>8} {:>12.4e} {:>12.4e}", "scheme", scheme, gm, gs);
        csv.row(&[
            "scheme".into(),
            "16n".into(),
            scheme.into(),
            format!("{gm:.6e}"),
            format!("{gs:.6e}"),
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    println!("\nwrote results/theory_gap.csv");
}
