//! **Figure 3** — UGW estimation error (vs the PGA-UGW benchmark) and
//! CPU time on Moon and Graph, ℓ1 and ℓ2 costs, unit total masses,
//! λ = 1.
//!
//! Methods: Naive (T = abᵀ/√(m(a)m(b))), EUGW, PGA-UGW, SaGroW (adapted
//! to unbalanced problems), Spar-UGW.
//!
//! Output: stdout series + `results/fig3_<ds>_<cost>.csv`.

use spargw::bench::workloads::{n_sweep, reps, Workload};
use spargw::bench::{repeat_timed, select_epsilon, EPS_GRID};
use spargw::gw::sagrow::{matched_s_prime, sagrow_ugw};
use spargw::gw::spar_ugw::{spar_ugw, SparUgwConfig};
use spargw::gw::ugw::{eugw, naive_ugw, pga_ugw, UgwConfig};
use spargw::gw::{GroundCost, GwProblem};
use spargw::rng::{derive_seed, Xoshiro256};
use spargw::util::csv::CsvWriter;

const LAMBDA: f64 = 1.0;

#[derive(Clone, Copy, PartialEq)]
enum UMethod {
    Naive,
    Eugw,
    PgaUgw,
    SagrowU,
    SparUgw,
}

impl UMethod {
    fn name(self) -> &'static str {
        match self {
            UMethod::Naive => "Naive",
            UMethod::Eugw => "EUGW",
            UMethod::PgaUgw => "PGA-UGW",
            UMethod::SagrowU => "SaGroW",
            UMethod::SparUgw => "Spar-UGW",
        }
    }

    fn is_sampled(self) -> bool {
        matches!(self, UMethod::SagrowU | UMethod::SparUgw)
    }

    fn run(self, p: &GwProblem, cost: GroundCost, eps: f64, outer: usize, seed: u64) -> f64 {
        let cfg =
            UgwConfig { lambda: LAMBDA, epsilon: eps, outer_iters: outer, ..Default::default() };
        let n = p.n().max(p.m());
        let mut rng = Xoshiro256::new(seed);
        match self {
            UMethod::Naive => naive_ugw(p, cost, LAMBDA),
            UMethod::Eugw => eugw(p, cost, &cfg).value,
            UMethod::PgaUgw => pga_ugw(p, cost, &cfg).value,
            UMethod::SagrowU => {
                let sp = matched_s_prime(16 * n, p.m(), p.n());
                sagrow_ugw(p, cost, sp, &cfg, &mut rng).value
            }
            UMethod::SparUgw => {
                let scfg = SparUgwConfig { ugw: cfg, sample_size: 16 * n, shrink: 0.0 };
                spar_ugw(p, cost, &scfg, &mut rng).value
            }
        }
    }
}

fn main() {
    let ns = n_sweep();
    let reps = reps();
    let methods =
        [UMethod::Naive, UMethod::Eugw, UMethod::PgaUgw, UMethod::SagrowU, UMethod::SparUgw];
    println!("Figure 3: UGW error + CPU time (λ = {LAMBDA}, reps = {reps}, n in {ns:?})");

    for workload in [Workload::Moon, Workload::Graph] {
        for cost in [GroundCost::L1, GroundCost::L2] {
            let tag = format!("fig3_{}_{}", workload.name().to_lowercase(), cost.name());
            let mut csv = CsvWriter::create(
                format!("results/{tag}.csv"),
                &["method", "n", "error_mean", "error_sd", "time_mean", "eps"],
            )
            .expect("csv");
            println!("\n== {} / {} ==", workload.name(), cost.name());
            println!(
                "{:<9} {:>5} {:>12} {:>12} {:>10} {:>9}",
                "method", "n", "err_mean", "err_sd", "time[s]", "eps"
            );

            for (ni, &n) in ns.iter().enumerate() {
                let mut grng = Xoshiro256::new(derive_seed(0xF163, (ni * 4) as u64));
                let inst = workload.make(n, &mut grng);
                let p = inst.problem();

                let benchmark = UMethod::PgaUgw.run(&p, cost, 0.001, 20, 1);

                for &method in &methods {
                    let n_reps = if method.is_sampled() { reps } else { 1 };
                    // Cheap pilot (R = 6) for the ε grid, full run after.
                    let (_, eps, _) = select_epsilon(&EPS_GRID, |e| {
                        (method.run(&p, cost, e, 6, derive_seed(5, e.to_bits())), 0.0)
                    });
                    let stats = repeat_timed(n_reps, |r| {
                        method.run(&p, cost, eps, 20, derive_seed(13, r as u64))
                    });
                    let err = (stats.value_mean - benchmark).abs();
                    println!(
                        "{:<9} {:>5} {:>12.4e} {:>12.4e} {:>10.4} {:>9}",
                        method.name(),
                        n,
                        err,
                        stats.value_sd,
                        stats.time_mean,
                        eps
                    );
                    csv.row(&[
                        method.name().into(),
                        n.to_string(),
                        format!("{err:.6e}"),
                        format!("{:.6e}", stats.value_sd),
                        format!("{:.6e}", stats.time_mean),
                        eps.to_string(),
                    ])
                    .unwrap();
                }
            }
            csv.flush().unwrap();
            println!("wrote results/{tag}.csv");
        }
    }
}
