//! **Table 3** — graph classification: pairwise (F)GW matrix →
//! similarity kernel `exp(−D/γ)` → kernel SVM → ten-fold cross-validated
//! accuracy (%), γ selected by inner validation over powers of two.
//!
//! Output: the table on stdout + `results/table3.csv`.

use spargw::bench::workloads::{full_mode, smoke_mode};
use spargw::bench::{pairwise_distances, Method, RunSettings};
use spargw::coordinator::service::similarity_from_distances;
use spargw::datasets::graphsets::all_datasets;
use spargw::gw::GroundCost;
use spargw::linalg::Mat;
use spargw::ml::{cross_validate, KernelSvm, SvmConfig};
use spargw::rng::Xoshiro256;
use spargw::util::csv::CsvWriter;

/// Ten-fold CV accuracy at the best γ of the grid.
fn classify_score(d: &Mat, labels: &[usize], seed: u64) -> f64 {
    let gammas: Vec<f64> = (-10..=10).step_by(2).map(|e| 2f64.powi(e)).collect();
    let mut best = f64::NEG_INFINITY;
    for &gamma in &gammas {
        let sim = similarity_from_distances(d, gamma);
        let mut rng = Xoshiro256::new(seed);
        let folds = 10.min(labels.len() / 2).max(2);
        let acc = cross_validate(&sim, labels, folds, &mut rng, |k_train, y| {
            let svm = KernelSvm::train(k_train, y, &SvmConfig::default());
            Box::new(move |k_test: &Mat| svm.predict(k_test))
        });
        best = best.max(acc);
    }
    best
}

fn main() {
    let seed = 7u64;
    let workers = 4;
    let mut datasets = all_datasets(seed);
    if !full_mode() {
        for ds in &mut datasets {
            let cap = if smoke_mode() {
                8
            } else if ds.mean_nodes() > 50.0 {
                12
            } else {
                20
            };
            ds.graphs.truncate(cap);
        }
    }

    let rows: Vec<(Method, GroundCost)> = vec![
        (Method::Egw, GroundCost::L2),
        (Method::Sgwl, GroundCost::L2),
        (Method::LrGw, GroundCost::L2),
        (Method::Anchor, GroundCost::L2),
        (Method::Anchor, GroundCost::L1),
        (Method::Sagrow, GroundCost::L2),
        (Method::Sagrow, GroundCost::L1),
        (Method::SparGw, GroundCost::L2),
        (Method::SparGw, GroundCost::L1),
    ];

    let mut csv =
        CsvWriter::create("results/table3.csv", &["method", "cost", "dataset", "accuracy"])
            .expect("csv");

    print!("{:<22}", "method");
    for ds in &datasets {
        print!(" {:>12}", ds.name);
    }
    println!();

    for (method, cost) in rows {
        print!("{:<22}", format!("{} ({})", method.name(), cost.name()));
        for ds in &datasets {
            let st = RunSettings::default();
            let d = pairwise_distances(ds, method, cost, &st, workers, seed);
            let acc = classify_score(&d, &ds.labels(), seed ^ 0xC3);
            print!(" {:>12.2}", 100.0 * acc);
            csv.row(&[
                method.name().into(),
                cost.name().into(),
                ds.name.into(),
                format!("{:.4}", 100.0 * acc),
            ])
            .unwrap();
        }
        println!();
    }
    csv.flush().unwrap();
    println!("\nwrote results/table3.csv");
}
