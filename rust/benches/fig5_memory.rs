//! **Figure 5** (Appendix C.1) — estimation error, CPU time **and peak
//! memory** on the Gaussian and Spiral datasets.
//!
//! Memory is measured the paper's way — "the difference between peak and
//! initial memory" — via the counting global allocator installed below.
//!
//! Output: stdout series + `results/fig5_<ds>_<cost>.csv`.

use spargw::bench::workloads::{n_sweep, reps, Workload};
use spargw::bench::{
    peak_bytes_during, repeat_timed, select_epsilon, CountingAllocator, Method, RunSettings,
    EPS_GRID,
};
use spargw::gw::GroundCost;
use spargw::rng::{derive_seed, Xoshiro256};
use spargw::util::csv::CsvWriter;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn main() {
    let ns = n_sweep();
    let reps = reps();
    println!("Figure 5: error + time + peak memory (reps = {reps}, n in {ns:?})");

    for workload in [Workload::Gaussian, Workload::Spiral] {
        for cost in [GroundCost::L1, GroundCost::L2] {
            let tag = format!("fig5_{}_{}", workload.name().to_lowercase(), cost.name());
            let mut csv = CsvWriter::create(
                format!("results/{tag}.csv"),
                &["method", "n", "error_mean", "time_mean", "peak_mem_mb", "eps"],
            )
            .expect("csv");
            println!("\n== {} / {} ==", workload.name(), cost.name());
            println!(
                "{:<9} {:>5} {:>12} {:>10} {:>12} {:>9}",
                "method", "n", "err_mean", "time[s]", "peak_mem_MB", "eps"
            );

            for (ni, &n) in ns.iter().enumerate() {
                let mut grng = Xoshiro256::new(derive_seed(0xF165, (ni * 4) as u64));
                let inst = workload.make(n, &mut grng);
                let p = inst.problem();

                let bench_settings = RunSettings { epsilon: 0.001, ..Default::default() };
                let mut brng = Xoshiro256::new(1);
                let benchmark =
                    Method::PgaGw.run(&p, None, cost, &bench_settings, &mut brng).unwrap().value;

                for &method in Method::fig2_lineup() {
                    if !method.supports_cost(cost) {
                        continue;
                    }
                    let n_reps = if method.is_sampled() { reps } else { 1 };
                    // ε selection uses a cheap pilot (R = 6): the chosen ε
                    // is then re-run at full depth for the reported stats.
                    let (_, eps, _) = select_epsilon(&EPS_GRID, |e| {
                        let st =
                            RunSettings { epsilon: e, outer_iters: 6, ..Default::default() };
                        let mut rng = Xoshiro256::new(derive_seed(7, e.to_bits()));
                        let out = method.run(&p, None, cost, &st, &mut rng).unwrap();
                        (out.value, out.seconds)
                    });
                    let st = RunSettings { epsilon: eps, ..Default::default() };
                    // Peak memory on one run; time/value stats over reps.
                    let (_, peak) = peak_bytes_during(|| {
                        let mut rng = Xoshiro256::new(derive_seed(19, 0));
                        method.run(&p, None, cost, &st, &mut rng)
                    });
                    let stats = repeat_timed(n_reps, |r| {
                        let mut rng = Xoshiro256::new(derive_seed(19, r as u64));
                        method.run(&p, None, cost, &st, &mut rng).unwrap().value
                    });
                    let err = (stats.value_mean - benchmark).abs();
                    let mb = peak as f64 / (1024.0 * 1024.0);
                    println!(
                        "{:<9} {:>5} {:>12.4e} {:>10.4} {:>12.3} {:>9}",
                        method.name(),
                        n,
                        err,
                        stats.time_mean,
                        mb,
                        eps
                    );
                    csv.row(&[
                        method.name().into(),
                        n.to_string(),
                        format!("{err:.6e}"),
                        format!("{:.6e}", stats.time_mean),
                        format!("{mb:.4}"),
                        eps.to_string(),
                    ])
                    .unwrap();
                }
            }
            csv.flush().unwrap();
            println!("wrote results/{tag}.csv");
        }
    }
}
