//! **Figure 4** — sensitivity of Spar-GW to the subsample size s and the
//! regularization parameter ε: heat maps of the estimated GW distance
//! (panel a) and CPU time (panel b) over
//! `s ∈ {2¹, …, 2⁵}·n × ε ∈ {5⁰, …, 5⁻⁴}` at fixed n = 200,
//! averaged over ten runs.
//!
//! Output: both heat maps on stdout + `results/fig4_<ds>.csv`.

use spargw::bench::workloads::{reps, Workload};
use spargw::bench::repeat_timed;
use spargw::gw::spar_gw::{spar_gw, SparGwConfig};
use spargw::gw::GroundCost;
use spargw::rng::{derive_seed, Xoshiro256};
use spargw::util::csv::CsvWriter;

fn main() {
    let n = 200;
    let reps = reps().max(5);
    let s_mults: Vec<usize> = vec![2, 4, 8, 16, 32];
    let eps_grid: Vec<f64> = (0..5).map(|k| 5f64.powi(-k)).collect();
    println!("Figure 4: Spar-GW sensitivity (n = {n}, reps = {reps})");

    for workload in [Workload::Moon, Workload::Graph] {
        let mut grng = Xoshiro256::new(0xF164);
        let inst = workload.make(n, &mut grng);
        let p = inst.problem();

        let tag = format!("fig4_{}", workload.name().to_lowercase());
        let mut csv = CsvWriter::create(
            format!("results/{tag}.csv"),
            &["s_mult", "eps", "gw_mean", "gw_sd", "time_mean"],
        )
        .expect("csv");

        let mut val_grid = vec![vec![0.0; eps_grid.len()]; s_mults.len()];
        let mut time_grid = vec![vec![0.0; eps_grid.len()]; s_mults.len()];
        for (si, &sm) in s_mults.iter().enumerate() {
            for (ei, &eps) in eps_grid.iter().enumerate() {
                let cfg = SparGwConfig {
                    epsilon: eps,
                    sample_size: sm * n,
                    ..Default::default()
                };
                let stats = repeat_timed(reps, |r| {
                    let mut rng = Xoshiro256::new(derive_seed(17, (r * 64 + si * 8 + ei) as u64));
                    spar_gw(&p, GroundCost::L2, &cfg, &mut rng).value
                });
                val_grid[si][ei] = stats.value_mean;
                time_grid[si][ei] = stats.time_mean;
                csv.row(&[
                    sm.to_string(),
                    eps.to_string(),
                    format!("{:.6e}", stats.value_mean),
                    format!("{:.6e}", stats.value_sd),
                    format!("{:.6e}", stats.time_mean),
                ])
                .unwrap();
            }
        }
        csv.flush().unwrap();

        for (label, grid) in
            [("(a) estimated GW", &val_grid), ("(b) CPU time [s]", &time_grid)]
        {
            println!("\n== {} — {label} ==", workload.name());
            print!("{:>8}", "s\\eps");
            for &eps in &eps_grid {
                print!(" {eps:>10.4}");
            }
            println!();
            for (si, &sm) in s_mults.iter().enumerate() {
                print!("{:>7}n", sm);
                for ei in 0..eps_grid.len() {
                    print!(" {:>10.3e}", grid[si][ei]);
                }
                println!();
            }
        }
        println!("wrote results/{tag}.csv");
    }
}
