//! **Ablation** (DESIGN.md §3) — does the importance part of importance
//! sparsification matter? Compare Spar-GW error under
//!
//! * the paper's Eq. (5) probabilities `p ∝ √(a_i b_j)`,
//! * uniform sampling (`shrink = 1`),
//! * the (H.4) mixture at θ = 0.5,
//!
//! at a fixed budget s, on a workload with *skewed* marginals (uniform
//! marginals make all three coincide — Moon/Graph both have strongly
//! non-uniform marginals).
//!
//! Expected shape: Eq. (5) ≤ mixture ≤ uniform in error, with the gap
//! growing as s shrinks.
//!
//! Output: stdout series + `results/ablation_sampling.csv`.

use spargw::bench::workloads::{reps, Workload};
use spargw::bench::{repeat_timed, Method, RunSettings};
use spargw::gw::sampling::GwSampler;
use spargw::gw::spar_gw::{spar_gw_with_set, SparGwConfig};
use spargw::gw::GroundCost;
use spargw::rng::{derive_seed, Xoshiro256};
use spargw::util::csv::CsvWriter;

fn main() {
    let n = 150;
    let reps = reps().max(5);
    let mut csv = CsvWriter::create(
        "results/ablation_sampling.csv",
        &["workload", "scheme", "s_mult", "error_mean", "error_sd"],
    )
    .expect("csv");
    println!("Ablation: Eq. (5) importance sampling vs uniform (n = {n}, reps = {reps})\n");

    for workload in [Workload::Moon, Workload::Graph] {
        let mut grng = Xoshiro256::new(0xAB1A);
        let inst = workload.make(n, &mut grng);
        let p = inst.problem();

        // Dense benchmark for the error reference.
        let mut brng = Xoshiro256::new(1);
        let st = RunSettings { epsilon: 0.001, ..Default::default() };
        let benchmark =
            Method::PgaGw.run(&p, None, GroundCost::L2, &st, &mut brng).unwrap().value;

        println!("== {} (benchmark GW = {benchmark:.4e}) ==", workload.name());
        println!("{:<12} {:>6} {:>12} {:>12}", "scheme", "s", "err_mean", "err_sd");
        for &(scheme, shrink) in
            &[("eq5", 0.0f64), ("mix-0.5", 0.5), ("uniform", 1.0)]
        {
            for &s_mult in &[4usize, 8, 16] {
                let s = s_mult * n;
                let cfg = SparGwConfig { sample_size: s, ..Default::default() };
                let stats = repeat_timed(reps, |r| {
                    let mut rng =
                        Xoshiro256::new(derive_seed(0xAB, (r * 64 + s_mult) as u64));
                    let sampler = GwSampler::new(p.a, p.b, shrink);
                    let set = sampler.sample_iid(&mut rng, s);
                    spar_gw_with_set(&p, GroundCost::L2, &cfg, &set).value
                });
                let err = (stats.value_mean - benchmark).abs();
                println!(
                    "{:<12} {:>5}n {:>12.4e} {:>12.4e}",
                    scheme, s_mult, err, stats.value_sd
                );
                csv.row(&[
                    workload.name().into(),
                    scheme.into(),
                    s_mult.to_string(),
                    format!("{err:.6e}"),
                    format!("{:.6e}", stats.value_sd),
                ])
                .unwrap();
            }
        }
        println!();
    }
    csv.flush().unwrap();
    println!("wrote results/ablation_sampling.csv");
}
