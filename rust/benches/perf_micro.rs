//! **§Perf micro-benchmarks** — the hot paths of the L3 coordinator and
//! the native Spar-GW solver, individually timed so the optimization log
//! in EXPERIMENTS.md §Perf has stable before/after numbers:
//!
//! * alias-table construction + s categorical draws (sampling S);
//! * the O(s²) sparse cost product `C̃(T̃)` (the paper's bottleneck),
//!   serial and pool-chunked at several thread widths;
//! * one sparse Sinkhorn scaling pass (O(Hs));
//! * dense decomposable vs generic tensor product (the baseline cost);
//! * end-to-end Spar-GW solve latency, cold and with a reused
//!   `SparCore` workspace;
//! * the hierarchical tier: one qgw solve from the raw point cloud
//!   (partition + coarse + extension) and one factored lr_gw mirror
//!   descent at the same n.
//!
//! This binary also installs the counting allocator and **verifies the
//! zero-allocations-per-iteration property** of the SparCore inner loop
//! and of the workspace-backed dense log-domain Sinkhorn
//! (`sinkhorn_log_into` with a warm `SinkhornLogScratch`): a solve at
//! R = 3 and a solve at R = 24 must perform exactly the same number of
//! allocation events (every allocation happens before the outer loop).
//! A regression aborts the bench with a non-zero exit.
//!
//! It also emits the **thread-scaling matrix** — wall time and speedup
//! for the blocked matmul, CSR spmm, fixed sparse Sinkhorn, the gathered
//! cost product, the Eq. (5) `SideFactors` build and a single-pair
//! Spar-GW solve at pool widths 1/2/4/8 — to
//! `results/BENCH_threads.json` (uploaded as a CI artifact to seed the
//! perf trajectory), and the **scalar-vs-SIMD matrix** — the dispatched
//! vector kernels against the portable schedule they reproduce
//! bit-for-bit, per precision at pool widths 1/8 — into
//! `results/BENCH_kernels.json`, alongside the **strict-vs-fast
//! numerics matrix** (same kernels plus the fused Sinkhorn sweep,
//! timed under both `NumericsPolicy` tiers on the best backend). Both
//! JSON artifacts are also copied to the repository root (the tracked
//! perf-trajectory snapshots).
//!
//! Output: stdout rows + `results/perf_micro.csv`.

use std::time::Instant;

use spargw::bench::workloads::{smoke_mode, Workload};
use spargw::bench::{allocations_during, CountingAllocator};
use spargw::gw::core::Workspace;
use spargw::gw::sampling::{GwSampler, SideFactors};
use spargw::gw::spar_gw::{spar_gw, spar_gw_with_workspace, SparGwConfig};
use spargw::gw::spar_ugw::{spar_ugw_with_workspace, SparUgwConfig};
use spargw::gw::tensor::{
    tensor_product_decomposable, tensor_product_generic, SparseCostContext,
};
use spargw::gw::ugw::UgwConfig;
use spargw::gw::GroundCost;
use spargw::kernel::simd::{self, Backend, NumericsPolicy};
use spargw::linalg::Mat;
use spargw::ot::{
    sinkhorn_log, sinkhorn_log_into, sparse_sinkhorn, sparse_sinkhorn_fixed, SinkhornLogScratch,
};
use spargw::rng::{ProductAlias, Xoshiro256};
use spargw::runtime::pool::with_thread_limit;
use spargw::sparse::{Coo, Csr};
use spargw::util::csv::CsvWriter;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Median-of-`reps` wall time of `f` (seconds), with a warmup call.
fn bench(reps: usize, mut f: impl FnMut()) -> f64 {
    f();
    let mut ts: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ts[ts.len() / 2]
}

fn main() {
    // SPARGW_BENCH_SMOKE=1 shrinks the instance for the CI allocation
    // audit (the zero-alloc property is size-independent).
    let (n, reps) = if smoke_mode() { (64, 2) } else { (200, 5) };
    let s = 16 * n;
    let mut rng = Xoshiro256::new(0x9E4F);
    let inst = Workload::Moon.make(n, &mut rng);
    let p = inst.problem();
    let mut csv =
        CsvWriter::create("results/perf_micro.csv", &["bench", "n", "s", "seconds"]).expect("csv");
    let mut emit = |name: &str, secs: f64| {
        println!("{name:<34} {secs:>12.6}s");
        csv.row(&[name.into(), n.to_string(), s.to_string(), format!("{secs:.6e}")]).unwrap();
    };
    println!("perf_micro: n = {n}, s = {s} (median of {reps})\n");

    // 1. Sampling S: product-alias build + s draws.
    let t = bench(reps, || {
        let alias = ProductAlias::new(p.a, p.b);
        let mut r = Xoshiro256::new(1);
        std::hint::black_box(alias.sample_many(&mut r, s));
    });
    emit("alias_build_plus_draws", t);

    // 2. Importance sampler end-to-end (probabilities + dedup + weights).
    let t = bench(reps, || {
        let sampler = GwSampler::new(p.a, p.b, 0.0);
        let mut r = Xoshiro256::new(2);
        std::hint::black_box(sampler.sample_iid(&mut r, s));
    });
    emit("gw_sampler_sample_iid", t);

    // Shared sampled set for the kernel benches.
    let sampler = GwSampler::new(p.a, p.b, 0.0);
    let mut r = Xoshiro256::new(3);
    let set = sampler.sample_iid(&mut r, s);
    let s_eff = set.len();
    let t_vals: Vec<f64> =
        set.rows.iter().zip(&set.cols).map(|(&i, &j)| p.a[i] * p.b[j]).collect();

    // 3. SparseCostContext construction (gathers the s×s relation values).
    let t = bench(reps, || {
        std::hint::black_box(SparseCostContext::new(
            p.cx, p.cy, &set.rows, &set.cols, GroundCost::L1,
        ));
    });
    emit("sparse_ctx_build_l1", t);

    // 4. The O(s²) sparse cost product — the paper's inner-loop bottleneck
    //    — serial, then row-chunked across threads (bit-identical output).
    let ctx_l1 = SparseCostContext::new(p.cx, p.cy, &set.rows, &set.cols, GroundCost::L1);
    let mut c_out = vec![0.0f64; s_eff];
    let t = bench(reps, || {
        ctx_l1.cost_values_into(&t_vals, &mut c_out);
        std::hint::black_box(&c_out);
    });
    emit("sparse_cost_product_l1", t);
    for width in [2usize, 4, 8] {
        let t = with_thread_limit(width, || {
            bench(reps, || {
                ctx_l1.cost_values_into_threaded(&t_vals, &mut c_out);
                std::hint::black_box(&c_out);
            })
        });
        emit(&format!("sparse_cost_product_l1_t{width}"), t);
    }
    let ctx_l2 = SparseCostContext::new(p.cx, p.cy, &set.rows, &set.cols, GroundCost::L2);
    let t = bench(reps, || {
        ctx_l2.cost_values_into(&t_vals, &mut c_out);
        std::hint::black_box(&c_out);
    });
    emit("sparse_cost_product_l2", t);

    // 5. Sparse Sinkhorn pass (H = 50).
    let k = Coo::from_triplets(n, n, &set.rows, &set.cols, &t_vals);
    let t = bench(reps, || {
        std::hint::black_box(sparse_sinkhorn(p.a, p.b, &k, 50, 0.0));
    });
    emit("sparse_sinkhorn_h50", t);

    // 5b. Dense log-domain Sinkhorn (H = 30) over the n×n relation
    //     matrix — the stabilized baseline path `egw` pays per outer
    //     iteration. Under the default strict tier this times the
    //     historical division-form LSE sweeps; re-run with
    //     SPARGW_NUMERICS=fast to time the fused subtract-max/exp
    //     sweeps (the strict-vs-fast matrix below isolates that delta).
    let t = bench(reps, || {
        std::hint::black_box(sinkhorn_log(p.a, p.b, p.cx, 0.1, 30, 0.0));
    });
    emit("log_domain_sinkhorn_h30", t);

    // 6. Dense tensor products at the same n (the baselines' inner loop).
    let tplan = Mat::outer(p.a, p.b);
    let t = bench(reps, || {
        std::hint::black_box(tensor_product_decomposable(p.cx, p.cy, &tplan, GroundCost::L2));
    });
    emit("dense_tensor_decomposable_l2", t);
    let t = bench(3, || {
        std::hint::black_box(tensor_product_generic(p.cx, p.cy, &tplan, GroundCost::L1));
    });
    emit("dense_tensor_generic_l1", t);

    // 7. End-to-end Spar-GW solve (R = 20, H = 50): cold (workspace
    //    allocated per solve) vs the coordinator's reuse pattern.
    let cfg = SparGwConfig { sample_size: s, ..Default::default() };
    let t = bench(reps, || {
        let mut r = Xoshiro256::new(4);
        std::hint::black_box(spar_gw(&p, GroundCost::L1, &cfg, &mut r));
    });
    emit("spar_gw_end_to_end_l1", t);
    let mut ws = Workspace::new();
    let t = bench(reps, || {
        std::hint::black_box(spar_gw_with_workspace(&p, GroundCost::L1, &cfg, &set, &mut ws));
    });
    emit("spar_gw_ws_reuse_l1", t);

    // 7b. Hierarchical tier rows: qgw end-to-end from the point cloud
    //     (no n×n allocation on its path) and the factored lr_gw descent
    //     on the dense instance, both at the same n.
    let tier_base = spargw::gw::solver::SolverBase { outer_iters: 5, ..Default::default() };
    let qsolver = spargw::gw::qgw::build(&Default::default(), &tier_base).expect("qgw build");
    let mut qrng = Xoshiro256::new(0x99);
    let (qsrc, qtgt) = spargw::datasets::moon::moon_points(n, 0.05, &mut qrng);
    let qpx = spargw::gw::PointCloud::from_points(&qsrc);
    let qpy = spargw::gw::PointCloud::from_points(&qtgt);
    let qa = spargw::util::uniform(n);
    let t = bench(reps, || {
        let mut r = Xoshiro256::new(6);
        let rep = qsolver.solve_points(&qpx, &qpy, &qa, &qa, &mut r, &mut ws).expect("qgw");
        std::hint::black_box(rep.value);
    });
    emit("qgw_points_end_to_end", t);
    let mut lr_opts = std::collections::BTreeMap::new();
    lr_opts.insert("outer".to_string(), "5".to_string());
    let lr_solver =
        spargw::gw::solver::SolverRegistry::build_with_base("lr_gw", &lr_opts, &tier_base)
            .expect("lr_gw build");
    let t = bench(reps, || {
        let mut r = Xoshiro256::new(7);
        let rep = lr_solver.solve(&p, &mut r, &mut ws).expect("lr_gw");
        std::hint::black_box(rep.value);
    });
    emit("lr_gw_factored_solve", t);

    // 8. Allocation audit: the SparCore inner loop must not allocate.
    //    Compare allocation events at two outer budgets on a warm
    //    workspace — any per-iteration allocation shows up as a delta.
    println!();
    let audit = |label: &str, allocs_lo: usize, allocs_hi: usize, iters_lo: usize, iters_hi: usize| {
        println!(
            "alloc_audit {label:<22} R={iters_lo}: {allocs_lo} allocs, R={iters_hi}: {allocs_hi} allocs"
        );
        assert_eq!(
            allocs_lo, allocs_hi,
            "ALLOCATION REGRESSION in {label}: the inner loop allocated \
             ({} extra events over {} extra iterations)",
            allocs_hi as i64 - allocs_lo as i64,
            iters_hi - iters_lo
        );
    };

    // Balanced (Spar-GW). tol = 0 pins the iteration counts.
    let gw_cfg = |outer| SparGwConfig { sample_size: s, outer_iters: outer, tol: 0.0, ..Default::default() };
    spar_gw_with_workspace(&p, GroundCost::L1, &gw_cfg(2), &set, &mut ws); // warm buffers + pool
    let (_, a3) = allocations_during(|| {
        spar_gw_with_workspace(&p, GroundCost::L1, &gw_cfg(3), &set, &mut ws)
    });
    let (_, a24) = allocations_during(|| {
        spar_gw_with_workspace(&p, GroundCost::L1, &gw_cfg(24), &set, &mut ws)
    });
    audit("spar_gw(balanced)", a3, a24, 3, 24);

    // Unbalanced (Spar-UGW): different inner solver, same property.
    let ucfg = |outer| SparUgwConfig {
        ugw: UgwConfig { outer_iters: outer, tol: 0.0, ..Default::default() },
        sample_size: s,
        shrink: 0.0,
    };
    spar_ugw_with_workspace(&p, GroundCost::L1, &ucfg(2), &set, &mut ws);
    let (_, u3) = allocations_during(|| {
        spar_ugw_with_workspace(&p, GroundCost::L1, &ucfg(3), &set, &mut ws)
    });
    let (_, u24) = allocations_during(|| {
        spar_ugw_with_workspace(&p, GroundCost::L1, &ucfg(24), &set, &mut ws)
    });
    audit("spar_ugw(unbalanced)", u3, u24, 3, 24);

    // Dense log-domain Sinkhorn: the `_into` form with a warm
    // `SinkhornLogScratch` and caller-owned plan/u/v must not allocate
    // per iteration either (tol = 0 pins the iteration counts; the
    // allocating `sinkhorn_log` wrapper is the convenience path).
    let mut lscratch = SinkhornLogScratch::new();
    let mut lplan = Mat::zeros(n, n);
    let (mut lu, mut lv) = (Vec::new(), Vec::new());
    sinkhorn_log_into(p.a, p.b, p.cx, 0.1, 2, 0.0, &mut lscratch, &mut lplan, &mut lu, &mut lv);
    let (_, d3) = allocations_during(|| {
        sinkhorn_log_into(p.a, p.b, p.cx, 0.1, 3, 0.0, &mut lscratch, &mut lplan, &mut lu, &mut lv)
    });
    let (_, d24) = allocations_during(|| {
        sinkhorn_log_into(p.a, p.b, p.cx, 0.1, 24, 0.0, &mut lscratch, &mut lplan, &mut lu, &mut lv)
    });
    audit("sinkhorn_log_into(dense)", d3, d24, 3, 24);

    // 9. Mixed-precision kernel matrix: f32 vs f64 throughput on the two
    //    Spar-GW hot kernels (fixed-sweep sparse Sinkhorn, gathered s×s
    //    cost product), emitted both as CSV rows and as the
    //    results/BENCH_kernels.json artifact CI uploads. The cost product
    //    is measured twice: at the full support (DRAM-streaming regime —
    //    the f32 cost block is shared by both precisions, so this bounds
    //    the bandwidth-limited gain) and on a cache-resident sub-block
    //    (compute-throughput regime, where the 8-wide convert-free f32
    //    lanes show their full advantage).
    println!();
    let mut kernel_rows: Vec<(String, f64, f64)> = Vec::new();

    // Sinkhorn: H = 50 fixed sweeps over the sampled CSR structure.
    let csr = Csr::from_pattern(n, n, &set.rows, &set.cols);
    let k64: Vec<f64> = t_vals.iter().map(|&x| x + 1e-6).collect();
    let k32: Vec<f32> = k64.iter().map(|&x| x as f32).collect();
    let a32: Vec<f32> = p.a.iter().map(|&x| x as f32).collect();
    let b32: Vec<f32> = p.b.iter().map(|&x| x as f32).collect();
    let (mut u64b, mut v64b, mut kv64, mut ktu64) =
        (vec![0.0f64; n], vec![0.0f64; n], vec![0.0f64; n], vec![0.0f64; n]);
    let mut plan64 = vec![0.0f64; s_eff];
    let t64 = bench(reps, || {
        sparse_sinkhorn_fixed(
            p.a, p.b, &csr, &k64, 50, &mut u64b, &mut v64b, &mut kv64, &mut ktu64, &mut plan64,
        );
        std::hint::black_box(&plan64);
    });
    let (mut u32b, mut v32b, mut kv32, mut ktu32) =
        (vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n]);
    let mut plan32 = vec![0.0f32; s_eff];
    let t32 = bench(reps, || {
        sparse_sinkhorn_fixed(
            &a32, &b32, &csr, &k32, 50, &mut u32b, &mut v32b, &mut kv32, &mut ktu32, &mut plan32,
        );
        std::hint::black_box(&plan32);
    });
    kernel_rows.push(("sparse_sinkhorn_fixed_h50".to_string(), t64, t32));

    // Gathered cost product, full support (bandwidth regime).
    let t_vals32: Vec<f32> = t_vals.iter().map(|&x| x as f32).collect();
    let mut c_out32 = vec![0.0f32; s_eff];
    let t64 = bench(reps, || {
        ctx_l1.cost_values_into(&t_vals, &mut c_out);
        std::hint::black_box(&c_out);
    });
    let t32 = bench(reps, || {
        ctx_l1.cost_values_into(&t_vals32, &mut c_out32);
        std::hint::black_box(&c_out32);
    });
    kernel_rows.push(("sparse_cost_product_full".to_string(), t64, t32));

    // Gathered cost product, cache-resident sub-block (compute regime):
    // the headline s×s tensor-product kernel throughput.
    let s_small = s_eff.min(1024);
    let ctx_small = SparseCostContext::new(
        p.cx,
        p.cy,
        &set.rows[..s_small],
        &set.cols[..s_small],
        GroundCost::L1,
    );
    let ts64: Vec<f64> = t_vals[..s_small].to_vec();
    let ts32: Vec<f32> = ts64.iter().map(|&x| x as f32).collect();
    let mut o64 = vec![0.0f64; s_small];
    let mut o32 = vec![0.0f32; s_small];
    // More inner repetitions: the sub-block is small, so time a batch.
    let batch = 32usize;
    let t64 = bench(reps, || {
        for _ in 0..batch {
            ctx_small.cost_values_into(&ts64, &mut o64);
        }
        std::hint::black_box(&o64);
    });
    let t32 = bench(reps, || {
        for _ in 0..batch {
            ctx_small.cost_values_into(&ts32, &mut o32);
        }
        std::hint::black_box(&o32);
    });
    kernel_rows.push(("sparse_cost_product_tile".to_string(), t64, t32));

    // 9b. Scalar-vs-SIMD matrix: each dispatched kernel family against
    //     the portable schedule it reproduces bit-for-bit, per precision
    //     and at pool widths 1 and 8 (the backend override is resolved at
    //     submit time, so pool chunks honor it at any width). Recorded as
    //     the `scalar_vs_simd` object in BENCH_kernels.json.
    println!();
    let best = simd::detect();
    println!("scalar vs simd backend = {} (pool widths 1/8)", best.name());
    let mut svs_rows: Vec<(&'static str, &'static str, usize, f64, f64)> = Vec::new();
    let mut svs = |kernel: &'static str, precision: &'static str, f: &mut dyn FnMut()| {
        for &w in &[1usize, 8] {
            let t_scalar = simd::with_backend_override(Backend::Scalar, || {
                with_thread_limit(w, || bench(reps, &mut *f))
            });
            let t_simd = simd::with_backend_override(best, || {
                with_thread_limit(w, || bench(reps, &mut *f))
            });
            println!(
                "{kernel:<18} {precision} w{w}  scalar {t_scalar:>11.6}s  {:<6} \
                 {t_simd:>11.6}s  speedup {:>5.2}x",
                best.name(),
                t_scalar / t_simd
            );
            svs_rows.push((kernel, precision, w, t_scalar, t_simd));
        }
    };

    // Blocked matmul micro-kernel (axpy rows inside the ikj tiles).
    let n_sv = if smoke_mode() { 96 } else { 320 };
    let sa64 = Mat::from_fn(n_sv, n_sv, |i, j| ((i * n_sv + j) as f64 * 0.11).sin());
    let sb64 = Mat::from_fn(n_sv, n_sv, |i, j| ((i + 3 * j) as f64 * 0.23).cos());
    let sa32: Mat<f32> = Mat::from_f64_mat(&sa64);
    let sb32: Mat<f32> = Mat::from_f64_mat(&sb64);
    svs("matmul_into", "f64", &mut || {
        std::hint::black_box(sa64.matmul(&sb64));
    });
    svs("matmul_into", "f32", &mut || {
        std::hint::black_box(sa32.matmul(&sb32));
    });
    // Gathered s×s cost product (gathered_dot_f64 / gathered_dot_f32).
    svs("gathered_dot", "f64", &mut || {
        ctx_l1.cost_values_into_threaded(&t_vals, &mut c_out);
        std::hint::black_box(&c_out);
    });
    svs("gathered_dot", "f32", &mut || {
        ctx_l1.cost_values_into_threaded(&t_vals32, &mut c_out32);
        std::hint::black_box(&c_out32);
    });

    for &(kernel, precision, w, t_scalar, t_simd) in &svs_rows {
        csv.row(&[
            format!("{kernel}_{precision}_w{w}_scalar"),
            n.to_string(),
            s.to_string(),
            format!("{t_scalar:.6e}"),
        ])
        .unwrap();
        csv.row(&[
            format!("{kernel}_{precision}_w{w}_simd"),
            n.to_string(),
            s.to_string(),
            format!("{t_simd:.6e}"),
        ])
        .unwrap();
    }

    // 9c. Strict-vs-fast numerics matrix: the same dispatched kernels
    //     plus the fused Sinkhorn sweep, timed under both tiers on the
    //     best backend (the policy override is captured at submit time
    //     exactly like the backend override, so pool chunks honor it at
    //     any width). Fast relaxes per-element rounding only — FMA
    //     contraction, the polynomial exp, and the fused scaling sweeps
    //     — never chunk boundaries or combine order. Recorded as the
    //     `strict_vs_fast` object in BENCH_kernels.json; the perf gate
    //     wants fast >= 1.3x on at least two kernels (non-fatal here,
    //     policed against the tracked snapshot).
    println!();
    println!("strict vs fast numerics, backend = {} (pool widths 1/8)", best.name());
    let mut svf_rows: Vec<(&'static str, &'static str, usize, f64, f64)> = Vec::new();
    let mut svf = |kernel: &'static str, precision: &'static str, f: &mut dyn FnMut()| {
        for &w in &[1usize, 8] {
            let t_strict = simd::with_backend_override(best, || {
                simd::with_numerics_override(NumericsPolicy::Strict, || {
                    with_thread_limit(w, || bench(reps, &mut *f))
                })
            });
            let t_fast = simd::with_backend_override(best, || {
                simd::with_numerics_override(NumericsPolicy::Fast, || {
                    with_thread_limit(w, || bench(reps, &mut *f))
                })
            });
            println!(
                "{kernel:<20} {precision} w{w}  strict {t_strict:>11.6}s  fast \
                 {t_fast:>11.6}s  speedup {:>5.2}x",
                t_strict / t_fast
            );
            svf_rows.push((kernel, precision, w, t_strict, t_fast));
        }
    };
    svf("matmul_into", "f64", &mut || {
        std::hint::black_box(sa64.matmul(&sb64));
    });
    svf("matmul_into", "f32", &mut || {
        std::hint::black_box(sa32.matmul(&sb32));
    });
    svf("gathered_dot", "f64", &mut || {
        ctx_l1.cost_values_into_threaded(&t_vals, &mut c_out);
        std::hint::black_box(&c_out);
    });
    svf("gathered_dot", "f32", &mut || {
        ctx_l1.cost_values_into_threaded(&t_vals32, &mut c_out32);
        std::hint::black_box(&c_out32);
    });
    // Fused Sinkhorn sweep: under fast the scaling update runs as the
    // single-traversal spmv_scale_fused kernels (no kv/ktu round trip).
    svf("sinkhorn_fused_sweep", "f64", &mut || {
        sparse_sinkhorn_fixed(
            p.a, p.b, &csr, &k64, 50, &mut u64b, &mut v64b, &mut kv64, &mut ktu64, &mut plan64,
        );
        std::hint::black_box(&plan64);
    });
    svf("sinkhorn_fused_sweep", "f32", &mut || {
        sparse_sinkhorn_fixed(
            &a32, &b32, &csr, &k32, 50, &mut u32b, &mut v32b, &mut kv32, &mut ktu32, &mut plan32,
        );
        std::hint::black_box(&plan32);
    });

    // Non-fatal target check: fast should clear 1.3x on at least two
    // distinct kernels at full bench size (smoke-mode timings are too
    // noisy to police).
    if !smoke_mode() {
        let cleared: std::collections::BTreeSet<&str> = svf_rows
            .iter()
            .filter(|&&(_, _, _, ts, tf)| ts / tf >= 1.3)
            .map(|&(k, _, _, _, _)| k)
            .collect();
        if cleared.len() < 2 {
            println!(
                "WARNING: fast tier cleared the 1.3x target on only {} kernel(s); \
                 target is >= 2 (recorded in results/BENCH_kernels.json)",
                cleared.len()
            );
        }
    }

    for &(kernel, precision, w, t_strict, t_fast) in &svf_rows {
        csv.row(&[
            format!("{kernel}_{precision}_w{w}_strict"),
            n.to_string(),
            s.to_string(),
            format!("{t_strict:.6e}"),
        ])
        .unwrap();
        csv.row(&[
            format!("{kernel}_{precision}_w{w}_fast"),
            n.to_string(),
            s.to_string(),
            format!("{t_fast:.6e}"),
        ])
        .unwrap();
    }

    // Artifacts land in results/ (CI upload) and at the repository root
    // (the tracked perf-trajectory snapshots the acceptance gates read).
    let write_artifact = |name: &str, contents: &str| {
        let local = format!("results/{name}");
        std::fs::write(&local, contents).unwrap_or_else(|e| panic!("write {local}: {e}"));
        println!("wrote {local}");
        if let Some(root) = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).parent() {
            let rp = root.join(name);
            match std::fs::write(&rp, contents) {
                Ok(()) => println!("wrote {}", rp.display()),
                Err(e) => println!("WARNING: cannot write {}: {e}", rp.display()),
            }
        }
    };

    // Emit the matrix: stdout, CSV rows, and the JSON artifact.
    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"n\": {n},\n  \"s\": {s},\n  \"s_effective\": {s_eff},\n  \"kernels\": [\n"
    ));
    for (i, (name, f64_secs, f32_secs)) in kernel_rows.iter().enumerate() {
        let speedup = f64_secs / f32_secs;
        println!(
            "{name:<34} f64 {f64_secs:>11.6}s   f32 {f32_secs:>11.6}s   speedup {speedup:>5.2}x"
        );
        // Non-fatal target check: the Sinkhorn sweep and the
        // cache-resident tile should clear 1.3x at full bench size (the
        // full-support row is bandwidth-bound — the f32 cost block is
        // shared by both precisions — so it is exempt, and smoke-mode
        // timings are too noisy to police).
        if !smoke_mode() && name != "sparse_cost_product_full" && speedup < 1.3 {
            println!(
                "WARNING: {name} f32 speedup {speedup:.2}x is below the 1.3x target \
                 (recorded in results/BENCH_kernels.json)"
            );
        }
        csv.row(&[
            format!("{name}_f64"),
            n.to_string(),
            s.to_string(),
            format!("{f64_secs:.6e}"),
        ])
        .unwrap();
        csv.row(&[
            format!("{name}_f32"),
            n.to_string(),
            s.to_string(),
            format!("{f32_secs:.6e}"),
        ])
        .unwrap();
        json.push_str(&format!(
            "    {{\"name\": \"{name}\", \"f64_seconds\": {f64_secs:.6e}, \
             \"f32_seconds\": {f32_secs:.6e}, \"speedup\": {speedup:.3}}}{}\n",
            if i + 1 < kernel_rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"scalar_vs_simd\": {{\n    \"simd_backend\": \"{}\",\n    \"widths\": [1, 8],\n    \
         \"rows\": [\n",
        best.name()
    ));
    for (i, &(kernel, precision, w, t_scalar, t_simd)) in svs_rows.iter().enumerate() {
        json.push_str(&format!(
            "      {{\"kernel\": \"{kernel}\", \"precision\": \"{precision}\", \"width\": {w}, \
             \"scalar_seconds\": {t_scalar:.6e}, \"simd_seconds\": {t_simd:.6e}, \
             \"speedup\": {:.3}}}{}\n",
            t_scalar / t_simd,
            if i + 1 < svs_rows.len() { "," } else { "" }
        ));
    }
    json.push_str("    ]\n  },\n");
    json.push_str(&format!(
        "  \"strict_vs_fast\": {{\n    \"simd_backend\": \"{}\",\n    \"widths\": [1, 8],\n    \
         \"rows\": [\n",
        best.name()
    ));
    for (i, &(kernel, precision, w, t_strict, t_fast)) in svf_rows.iter().enumerate() {
        json.push_str(&format!(
            "      {{\"kernel\": \"{kernel}\", \"precision\": \"{precision}\", \"width\": {w}, \
             \"strict_seconds\": {t_strict:.6e}, \"fast_seconds\": {t_fast:.6e}, \
             \"speedup\": {:.3}}}{}\n",
            t_strict / t_fast,
            if i + 1 < svf_rows.len() { "," } else { "" }
        ));
    }
    json.push_str("    ]\n  }\n}\n");
    write_artifact("BENCH_kernels.json", &json);

    // 10. Thread-scaling matrix: wall time + speedup at pool widths
    //     1/2/4/8 for every newly parallel kernel family plus a
    //     single-pair Spar-GW solve, emitted to
    //     results/BENCH_threads.json (the CI artifact seeding the perf
    //     trajectory). Widths above the machine's pool size clamp down,
    //     so the recorded machine_threads qualifies the tail columns.
    println!();
    let widths = [1usize, 2, 4, 8];
    let (n_mm, n_solve, s_mult) =
        if smoke_mode() { (128usize, 256usize, 16usize) } else { (384, 2000, 4) };
    let mut scaling: Vec<(String, Vec<f64>)> = Vec::new();

    // Dense blocked matmul (n_mm³ mul-adds).
    let ma = Mat::from_fn(n_mm, n_mm, |i, j| ((i * n_mm + j) as f64 * 0.13).sin());
    let mb = Mat::from_fn(n_mm, n_mm, |i, j| ((i + 2 * j) as f64 * 0.29).cos());
    let times: Vec<f64> = widths
        .iter()
        .map(|&w| {
            with_thread_limit(w, || {
                bench(reps, || {
                    std::hint::black_box(ma.matmul(&mb));
                })
            })
        })
        .collect();
    scaling.push(("dense_matmul".to_string(), times));

    // Deterministic-reduction self-check: checksum the matmul output via
    // the pool's fixed-chunk-order combine at serial and full width — the
    // partial sums must agree bit-for-bit (the reduce primitive's
    // determinism contract, exercised on real data in a shipped binary).
    let mm = ma.matmul(&mb);
    let checksum_at = |w: usize| {
        with_thread_limit(w, || {
            spargw::runtime::pool::pool().run_chunked_reduce(
                mm.data().len(),
                1 << 12,
                |range, _| mm.data()[range].iter().sum::<f64>(),
            )
        })
    };
    let (c1, cw) = (checksum_at(1), checksum_at(usize::MAX));
    assert_eq!(
        c1.to_bits(),
        cw.to_bits(),
        "run_chunked_reduce changed bits across widths: {c1} vs {cw}"
    );

    // CSR spmm over a 16·n_solve-entry pattern times a 32-wide dense block.
    let n_sp = n_solve;
    let mut rng_sp = Xoshiro256::new(0xAB5D);
    let sp_rows: Vec<usize> = (0..16 * n_sp).map(|_| rng_sp.usize(n_sp)).collect();
    let sp_cols: Vec<usize> = (0..16 * n_sp).map(|_| rng_sp.usize(n_sp)).collect();
    let sp_vals: Vec<f64> = (0..16 * n_sp).map(|_| rng_sp.f64() + 0.01).collect();
    let sp_csr = Csr::from_pattern(n_sp, n_sp, &sp_rows, &sp_cols);
    let bmat = Mat::from_fn(n_sp, 32, |i, j| ((i * 32 + j) as f64 * 0.17).sin());
    let mut spmm_out = Mat::zeros(n_sp, 32);
    let times: Vec<f64> = widths
        .iter()
        .map(|&w| {
            with_thread_limit(w, || {
                bench(reps, || {
                    sp_csr.matmul_into(&sp_vals, &bmat, &mut spmm_out);
                    std::hint::black_box(&spmm_out);
                })
            })
        })
        .collect();
    scaling.push(("csr_spmm".to_string(), times));

    // Fixed sparse Sinkhorn (H = 50) over the same pattern.
    let a_sp = spargw::util::uniform(n_sp);
    let (mut su, mut sv) = (vec![0.0f64; n_sp], vec![0.0f64; n_sp]);
    let (mut skv, mut sktu) = (vec![0.0f64; n_sp], vec![0.0f64; n_sp]);
    let mut splan = vec![0.0f64; 16 * n_sp];
    let times: Vec<f64> = widths
        .iter()
        .map(|&w| {
            with_thread_limit(w, || {
                bench(reps, || {
                    sparse_sinkhorn_fixed(
                        &a_sp, &a_sp, &sp_csr, &sp_vals, 50, &mut su, &mut sv, &mut skv,
                        &mut sktu, &mut splan,
                    );
                    std::hint::black_box(&splan);
                })
            })
        })
        .collect();
    scaling.push(("sparse_sinkhorn_fixed_h50".to_string(), times));

    // Single-pair Spar-GW solve at n_solve (the acceptance-criterion
    // row: the end-to-end pair latency the pairwise service pays), plus
    // its O(s²) cost product and the Eq. (5) factor build in isolation.
    let mut grng = Xoshiro256::new(0x501F);
    let inst2 = Workload::Moon.make(n_solve, &mut grng);
    let p2 = inst2.problem();
    let sampler2 = GwSampler::new(p2.a, p2.b, 0.0);
    let mut r2 = Xoshiro256::new(77);
    let set2 = sampler2.sample_iid(&mut r2, s_mult * n_solve);
    let ctx2 = SparseCostContext::new(p2.cx, p2.cy, &set2.rows, &set2.cols, GroundCost::L1);
    let tv2: Vec<f64> =
        set2.rows.iter().zip(&set2.cols).map(|(&i, &j)| p2.a[i] * p2.b[j]).collect();
    let mut co2 = vec![0.0f64; set2.len()];
    let times: Vec<f64> = widths
        .iter()
        .map(|&w| {
            with_thread_limit(w, || {
                bench(reps, || {
                    ctx2.cost_values_into_threaded(&tv2, &mut co2);
                    std::hint::black_box(&co2);
                })
            })
        })
        .collect();
    scaling.push(("sparse_cost_product".to_string(), times));

    let marg = spargw::util::uniform(if smoke_mode() { 1 << 16 } else { 1 << 20 });
    let times: Vec<f64> = widths
        .iter()
        .map(|&w| {
            with_thread_limit(w, || {
                bench(reps, || {
                    std::hint::black_box(SideFactors::new(&marg));
                })
            })
        })
        .collect();
    scaling.push(("side_factors_build".to_string(), times));

    let cfg2 = SparGwConfig {
        sample_size: s_mult * n_solve,
        outer_iters: 5,
        inner_iters: 20,
        tol: 0.0,
        ..Default::default()
    };
    let mut ws2 = Workspace::new();
    let times: Vec<f64> = widths
        .iter()
        .map(|&w| {
            with_thread_limit(w, || {
                bench(reps.min(3), || {
                    std::hint::black_box(spar_gw_with_workspace(
                        &p2,
                        GroundCost::L1,
                        &cfg2,
                        &set2,
                        &mut ws2,
                    ));
                })
            })
        })
        .collect();
    scaling.push(("spar_gw_single_pair_solve".to_string(), times));

    let machine_threads = spargw::runtime::pool::pool().threads();
    let mut tjson = String::from("{\n");
    tjson.push_str(&format!(
        "  \"n_solve\": {n_solve},\n  \"s_solve\": {},\n  \"machine_threads\": \
         {machine_threads},\n  \"widths\": [1, 2, 4, 8],\n  \"kernels\": [\n",
        set2.len()
    ));
    println!(
        "thread scaling (machine pool = {machine_threads} threads; widths clamp to it)"
    );
    for (ki, (name, times)) in scaling.iter().enumerate() {
        let base = times[0];
        let speedups: Vec<f64> = times.iter().map(|&t| base / t.max(1e-12)).collect();
        println!(
            "{name:<28} t1 {:>10.6}s  t2 {:>5.2}x  t4 {:>5.2}x  t8 {:>5.2}x",
            times[0], speedups[1], speedups[2], speedups[3]
        );
        for (wi, &w) in widths.iter().enumerate() {
            csv.row(&[
                format!("{name}_threads{w}"),
                n_solve.to_string(),
                set2.len().to_string(),
                format!("{:.6e}", times[wi]),
            ])
            .unwrap();
        }
        let secs: Vec<String> = times.iter().map(|t| format!("{t:.6e}")).collect();
        let sp: Vec<String> = speedups.iter().map(|x| format!("{x:.3}")).collect();
        tjson.push_str(&format!(
            "    {{\"name\": \"{name}\", \"seconds\": [{}], \"speedup\": [{}]}}{}\n",
            secs.join(", "),
            sp.join(", "),
            if ki + 1 < scaling.len() { "," } else { "" }
        ));
    }
    tjson.push_str("  ]\n}\n");
    write_artifact("BENCH_threads.json", &tjson);

    println!("\n(effective support |S| = {s_eff} of s = {s})");
    csv.flush().unwrap();
    println!("wrote results/perf_micro.csv");
}
