//! **Figure 6** (Appendix C.2) — fused-GW estimation error (vs dense
//! PGA-FGW) and CPU time on Moon and Graph with 5-dimensional Gaussian
//! node features, trade-off α = 0.6.
//!
//! Methods: Naive (T = abᵀ), EGW, PGA-GW, EMD-GW, SaGroW, Spar-FGW —
//! all on the fused objective.
//!
//! Output: stdout series + `results/fig6_<ds>_<cost>.csv`.

use spargw::bench::workloads::{attach_features, n_sweep, reps, Workload};
use spargw::bench::{repeat_timed, select_epsilon, Method, RunSettings, EPS_GRID};
use spargw::gw::GroundCost;
use spargw::rng::{derive_seed, Xoshiro256};
use spargw::util::csv::CsvWriter;

fn main() {
    let ns = n_sweep();
    let reps = reps();
    let methods = [
        Method::Naive,
        Method::Egw,
        Method::PgaGw,
        Method::EmdGw,
        Method::Sagrow,
        Method::SparGw,
    ];
    println!("Figure 6: FGW error + CPU time (α = 0.6, reps = {reps}, n in {ns:?})");

    for workload in [Workload::Moon, Workload::Graph] {
        for cost in [GroundCost::L1, GroundCost::L2] {
            let tag = format!("fig6_{}_{}", workload.name().to_lowercase(), cost.name());
            let mut csv = CsvWriter::create(
                format!("results/{tag}.csv"),
                &["method", "n", "error_mean", "error_sd", "time_mean", "eps"],
            )
            .expect("csv");
            println!("\n== {} / {} ==", workload.name(), cost.name());
            println!(
                "{:<9} {:>5} {:>12} {:>12} {:>10} {:>9}",
                "method", "n", "err_mean", "err_sd", "time[s]", "eps"
            );

            for (ni, &n) in ns.iter().enumerate() {
                let mut grng = Xoshiro256::new(derive_seed(0xF166, (ni * 4) as u64));
                let mut inst = workload.make(n, &mut grng);
                attach_features(&mut inst, &mut grng);
                let p = inst.problem();
                let feat = inst.feat.as_ref().unwrap();

                let bench_settings = RunSettings { epsilon: 0.001, ..Default::default() };
                let mut brng = Xoshiro256::new(1);
                let benchmark = Method::PgaGw
                    .run(&p, Some(feat), cost, &bench_settings, &mut brng)
                    .unwrap()
                    .value;

                for &method in &methods {
                    let n_reps = if method.is_sampled() { reps } else { 1 };
                    // ε selection uses a cheap pilot (R = 6): the chosen ε
                    // is then re-run at full depth for the reported stats.
                    let (_, eps, _) = select_epsilon(&EPS_GRID, |e| {
                        let st =
                            RunSettings { epsilon: e, outer_iters: 6, ..Default::default() };
                        let mut rng = Xoshiro256::new(derive_seed(7, e.to_bits()));
                        let out = method.run(&p, Some(feat), cost, &st, &mut rng).unwrap();
                        (out.value, out.seconds)
                    });
                    let st = RunSettings { epsilon: eps, ..Default::default() };
                    let stats = repeat_timed(n_reps, |r| {
                        let mut rng = Xoshiro256::new(derive_seed(23, r as u64));
                        method.run(&p, Some(feat), cost, &st, &mut rng).unwrap().value
                    });
                    let err = (stats.value_mean - benchmark).abs();
                    println!(
                        "{:<9} {:>5} {:>12.4e} {:>12.4e} {:>10.4} {:>9}",
                        method.name(),
                        n,
                        err,
                        stats.value_sd,
                        stats.time_mean,
                        eps
                    );
                    csv.row(&[
                        method.name().into(),
                        n.to_string(),
                        format!("{err:.6e}"),
                        format!("{:.6e}", stats.value_sd),
                        format!("{:.6e}", stats.time_mean),
                        eps.to_string(),
                    ])
                    .unwrap();
                }
            }
            csv.flush().unwrap();
            println!("wrote results/{tag}.csv");
        }
    }
}
