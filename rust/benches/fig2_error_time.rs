//! **Figure 2** — GW estimation error (vs the PGA-GW benchmark) and CPU
//! time on the Moon and Graph datasets, for ℓ1 and ℓ2 ground costs, as
//! the sample size n grows.
//!
//! Methods: EGW, PGA-GW, EMD-GW, S-GWL, LR-GW (ℓ2 only), SaGroW, Spar-GW.
//! Sampling-based methods are averaged over `reps()` runs. Each method's
//! ε is chosen from the paper's grid by the smallest-distance rule (§6.1).
//!
//! Output: the plotted series on stdout + `results/fig2_<ds>_<cost>.csv`.

use spargw::bench::workloads::{n_sweep, reps, Workload};
use spargw::bench::{repeat_timed, select_epsilon, Method, RunSettings, EPS_GRID};
use spargw::gw::GroundCost;
use spargw::rng::{derive_seed, Xoshiro256};
use spargw::util::csv::CsvWriter;

fn main() {
    let ns = n_sweep();
    let reps = reps();
    println!("Figure 2: estimation error + CPU time (reps = {reps}, n in {ns:?})");

    for workload in [Workload::Moon, Workload::Graph] {
        for cost in [GroundCost::L1, GroundCost::L2] {
            let tag = format!("fig2_{}_{}", workload.name().to_lowercase(), cost.name());
            let mut csv = CsvWriter::create(
                format!("results/{tag}.csv"),
                &["method", "n", "error_mean", "error_sd", "time_mean", "time_sd", "eps"],
            )
            .expect("csv");

            println!("\n== {} / {} ==", workload.name(), cost.name());
            println!(
                "{:<9} {:>5} {:>12} {:>12} {:>10} {:>9}",
                "method", "n", "err_mean", "err_sd", "time[s]", "eps"
            );

            for (ni, &n) in ns.iter().enumerate() {
                // One shared instance per n so every method sees the
                // same problem (the paper's protocol).
                let mut grng = Xoshiro256::new(derive_seed(0xF162, (ni * 4) as u64));
                let inst = workload.make(n, &mut grng);
                let p = inst.problem();

                // PGA-GW is the accuracy benchmark for the error column.
                let bench_settings = RunSettings { epsilon: 0.001, ..Default::default() };
                let mut brng = Xoshiro256::new(1);
                let benchmark = Method::PgaGw
                    .run(&p, None, cost, &bench_settings, &mut brng)
                    .unwrap()
                    .value;

                for &method in Method::fig2_lineup() {
                    if !method.supports_cost(cost) {
                        continue;
                    }
                    let n_reps = if method.is_sampled() { reps } else { 1 };
                    // ε grid selection on one rep, then stats at that ε.
                    // ε selection uses a cheap pilot (R = 6): the chosen ε
                    // is then re-run at full depth for the reported stats.
                    let (_, eps, _) = select_epsilon(&EPS_GRID, |e| {
                        let st =
                            RunSettings { epsilon: e, outer_iters: 6, ..Default::default() };
                        let mut rng = Xoshiro256::new(derive_seed(7, e.to_bits()));
                        let out = method.run(&p, None, cost, &st, &mut rng).unwrap();
                        (out.value, out.seconds)
                    });
                    let st = RunSettings { epsilon: eps, ..Default::default() };
                    let mut times = Vec::new();
                    let stats = repeat_timed(n_reps, |r| {
                        let mut rng = Xoshiro256::new(derive_seed(11, r as u64));
                        let out = method.run(&p, None, cost, &st, &mut rng).unwrap();
                        times.push(out.seconds);
                        out.value
                    });
                    let err_mean = (stats.value_mean - benchmark).abs();
                    println!(
                        "{:<9} {:>5} {:>12.4e} {:>12.4e} {:>10.4} {:>9}",
                        method.name(),
                        n,
                        err_mean,
                        stats.value_sd,
                        stats.time_mean,
                        eps
                    );
                    csv.row(&[
                        method.name().into(),
                        n.to_string(),
                        format!("{err_mean:.6e}"),
                        format!("{:.6e}", stats.value_sd),
                        format!("{:.6e}", stats.time_mean),
                        format!("{:.6e}", stats.time_sd),
                        eps.to_string(),
                    ])
                    .unwrap();
                }
            }
            csv.flush().unwrap();
            println!("wrote results/{tag}.csv");
        }
    }
}
